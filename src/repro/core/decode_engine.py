"""Parallel two-phase decompression: the `LZ4DecodeEngine` and `FrameReader`.

The mirror image of engine.py's compress pipeline.  `decode_frame` used to
walk blocks serially in Python, so every restore path (serving KV-offload,
checkpoint load, the data pipeline) was bottlenecked on one byte loop.  The
frame's blocks are independent by construction, which makes the read side
embarrassingly parallel (Sitaridi et al., arXiv 1606.00519):

  * each block is decoded in two phases — `plan_block_fast` parses the token
    stream once into flat NumPy copy arrays (feedback-free field extraction,
    decode_plan.py), `execute_plan` runs the literal/match copies in bulk;
  * independent blocks fan out across a worker pool.  Four executors:

      "serial"   — decode blocks inline.  The default: the planned decoder
                   already beats the old serial `decode_frame`, and on
                   GIL-bound CPython a thread pool cannot add more (see
                   EXPERIMENTS.md for measurements).
      "thread"   — ThreadPoolExecutor.  Pays on free-threaded builds and
                   when block decode offloads to an accelerator; on stock
                   CPython the GIL serializes the Python residue.
      "process"  — fork-based ProcessPoolExecutor, blocks round-trip as
                   bytes.  True multi-core decode on CPython.  Opt-in:
                   forking a process with live JAX threads is officially
                   discouraged (workers never touch JAX, and only the pool
                   fork happens, but create the engine early if you use it).
      "device"   — phase two runs INSIDE jit: host planning
                   (`plan_block_fast` -> `to_device_plan`) stacks a
                   micro-batch of fixed-shape `DevicePlan`s and ONE
                   vmapped+jitted `kernels.ops.decode_gather` dispatch
                   resolves and materializes every block's bytes on the
                   accelerator (pointer-doubling source resolve — see
                   decode_plan.py), double-buffered like the compress
                   engine.  The read-side mirror of `device_emit`:
                   `DecodeStats.host_bytes` counts exactly the decoded
                   bytes fetched back (or nothing, via
                   `decode_to_device` — the accelerator-to-accelerator
                   restore path used by serving KV-offload, whose CRC
                   verification also runs in-graph, so even verified
                   restores fetch no content).  Blocks whose
                   plans overflow the fixed caps fall back to the host
                   executor per block (counted in `fallback_blocks`).
                   With ``plan_on_device=True`` phase ONE moves in-graph
                   too: the speculative planner
                   (`kernels.plan_speculative`, validated/compacted by
                   `kernels.ops.plan_speculative`) parses the token
                   stream on device and `kernels.ops.plan_decode` fuses
                   plan + gather + CRC into a single dispatch — the last
                   host O(n) stage is gone, and `host_bytes == 0` on the
                   to-device paths now includes planning.  Malformed or
                   caps-overflowing blocks surface through a 5-lane
                   status vector; overflows replan on host (counted),
                   parse errors raise the host planner's exact message.

  * version-2 frames carry per-block CRC32s of the uncompressed content,
    verified as each block lands, so corruption is caught at the block that
    suffered it — never returned as silent wrong output.

`FrameReader` adds random access on top (Rapidgzip-style seek index,
arXiv 2308.08955): the frame's block table maps any decompressed byte range
to its covering blocks, so `read_range(start, length)` decodes only those
blocks — partial reads of a multi-gigabyte frame cost O(range), not
O(frame).  `read_block(i)` fetches a single block, with a small LRU so
repeated nearby reads (KV-offload restore of one request's slice) decode
each block once.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro import obs

from .decode_plan import (
    _ERR_MESSAGES,
    MAX_RESOLVE_ROUNDS,
    DevicePlanCaps,
    DevicePlanOverflow,
    execute_plan,
    plan_block_fast,
    to_device_plan,
)
from .decoder import LZ4FormatError, decode_block
from .frame import (
    FrameFormatError,
    block_crc,
    check_block,
    check_content_crc,
    frame_info,
)
from .lz4_types import MAX_BLOCK, pad_pow2_count

__all__ = ["LZ4DecodeEngine", "DecodeStats", "FrameReader",
           "default_decode_engine"]

_EXECUTORS = ("serial", "thread", "process", "device")


@functools.lru_cache(maxsize=None)
def _device_decode_compiled(out_cap: int, rounds: int, use_pallas: bool):
    """Jitted vmap of the single-block decode graph, cached per static
    config (shared across engine instances; jit's own cache then keys on
    the stacked batch shape, bounded by the power-of-two padding)."""
    import jax

    from repro.kernels.ops import decode_gather

    fn = functools.partial(decode_gather, out_cap=out_cap, rounds=rounds,
                           use_pallas=use_pallas)
    return jax.jit(jax.vmap(fn))


@functools.lru_cache(maxsize=None)
def _device_plan_decode_compiled(out_cap: int, max_lit: int, max_match: int,
                                 rounds: int, use_pallas: bool,
                                 compute_crc: bool):
    """Jitted vmap of the FUSED plan+decode(+CRC) graph (`kernels.ops.
    plan_decode`) — the speculative-planning twin of
    `_device_decode_compiled`.  One dispatch takes a stacked micro-batch of
    raw compressed payloads and returns decoded rows, per-block status
    vectors, and in-graph checksums: no token stream is ever parsed on
    host."""
    import jax

    from repro.kernels.ops import plan_decode

    fn = functools.partial(plan_decode, out_cap=out_cap, max_lit=max_lit,
                           max_match=max_match, rounds=rounds,
                           use_pallas=use_pallas, compute_crc=compute_crc)
    return jax.jit(jax.vmap(fn))


def _spec_err_message(code: int) -> str:
    """Map a speculative-planner status code to the host planner's exact
    error message (codes 1..8 are `_ERR_MESSAGES`; 9 is the serial parser's
    missing-token error — parity asserted in tests/test_plan_speculative.py)."""
    if code == 9:
        return "truncated block: missing token"
    return _ERR_MESSAGES.get(code, f"invalid stream (status {code})")


def _round_bucket(rounds: int) -> int:
    """Round the needed pointer-doubling depth up to a power of two so the
    number of compiled graph variants stays bounded ({0, 1, 2, 4, 8, 16})."""
    if rounds <= 0:
        return 0
    b = 1
    while b < rounds:
        b <<= 1
    return b


@functools.lru_cache(maxsize=1)
def default_decode_engine() -> "LZ4DecodeEngine":
    """Process-wide default engine (shared by decode_frame, serving,
    checkpointing, and the data pipeline).  Serial executor: safe under
    JAX, and the planned decoder is already faster than the byte loop it
    replaced; construct an engine with executor="process" for multi-core
    restores."""
    return LZ4DecodeEngine()


def _decode_planned(payload: bytes, cap: int, sp=None) -> bytes:
    """Two-phase decode of one block (plan once, execute in bulk).

    ``sp`` is an optional span factory (`obs.span_factory`) so the plan and
    execute phases show up as separate trace stages when telemetry is on.
    """
    if sp is None:
        plan = plan_block_fast(payload, max_out=cap)
        return execute_plan(payload, plan).tobytes()
    with sp("decode.plan", bytes_in=len(payload)):
        plan = plan_block_fast(payload, max_out=cap)
    with sp("decode.execute", bytes_out=plan.usize):
        return execute_plan(payload, plan).tobytes()


def _decode_one(payload: bytes, cap, two_phase: bool, ob: bool):
    """One block through the selected per-block decoder, traced when on.

    Spans recorded in thread-pool workers land in the shared tracer
    (per-thread buffers); spans in PROCESS-pool workers die with the child
    — the process executor is traced at the `decode.total` level only.
    """
    if not ob:
        return (_decode_planned(payload, cap) if two_phase
                else decode_block(payload, cap))
    sp = obs.span_factory(True)
    if two_phase:
        return _decode_planned(payload, cap, sp)
    with sp("decode.execute", bytes_in=len(payload), fused=True):
        return decode_block(payload, cap)


def _frame_block_task(args) -> bytes:
    """Decode + verify one frame block (runs in a worker for thread/process
    executors; module-level so it pickles for the process pool)."""
    payload, usize, crc, index, two_phase, ob = args
    try:
        data = _decode_one(payload, usize, two_phase, ob)
    except FrameFormatError:
        raise
    except LZ4FormatError as e:
        raise FrameFormatError(f"block {index}: {e}") from e
    if ob:
        with obs.span_factory(True)("decode.verify", block=index):
            check_block(index, usize, crc, data)
    else:
        check_block(index, usize, crc, data)
    return data


def _plain_block_task(args) -> bytes:
    """Decode one raw LZ4 block (no framing, no checksum)."""
    payload, usize, index, two_phase, ob = args
    cap = usize if usize is not None else MAX_BLOCK
    data = _decode_one(payload, cap, two_phase, ob)
    if usize is not None and len(data) != usize:
        raise LZ4FormatError(
            f"block {index}: decoded {len(data)} bytes, expected {usize}"
        )
    return data


@dataclasses.dataclass
class DecodeStats:
    """Per-call counters (PLUS a lifetime accumulator on the engine).

    Lifecycle — ``engine.stats`` is REPLACED at the start of every
    `decode` / `decode_blocks` / `decode_to_device` call: it describes the
    most recent call only (and `FrameReader` reads, which go through the
    engine's `_decode_entries*` internals WITHOUT a reset, increment the
    counters of whatever call came last).  For anything that must survive
    across calls use ``engine.totals``, the cumulative sum merged in as
    each public call finishes (even on error) — or the ``decode.*``
    counters in `repro.obs.registry()` when telemetry is on.

    ``host_bytes`` is the read-side twin of `EngineStats.host_bytes`: every
    CONTENT byte fetched device -> host by the "device" executor (exactly
    the decoded payload — rows are slice-fetched to their true usize — or
    zero for a `decode_to_device` restore, which never leaves the
    accelerator: its CRC verification runs in-graph and syncs only a
    4-byte checksum scalar, not counted here).  With ``plan_on_device``
    the zero covers PLANNING too — the speculative planner parses the
    token stream in-graph, and only the per-row status vector (a few
    int32 scalars per block, metadata like the CRC sync) crosses back.
    """

    blocks: int = 0
    raw_blocks: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    parallel: bool = False
    dispatches: int = 0        # device executor: jit dispatches issued
    device_blocks: int = 0     # blocks decoded inside the jit graph
    fallback_blocks: int = 0   # device executor blocks decoded on host
    host_bytes: int = 0        # bytes fetched device -> host
    shards: int = 0            # sharded-fabric calls: mesh shard count
    calls: int = 0             # 1 per finished call (totals.calls sums them)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def accumulate(self, other: "DecodeStats") -> None:
        """Fold ``other`` (one finished call) into this accumulator.

        NOT thread-safe by itself — the engine serializes its `totals`
        accumulation behind a lock (`_finish_call`); external accumulators
        shared across threads need their own.
        """
        for f in ("blocks", "raw_blocks", "bytes_in", "bytes_out",
                  "dispatches", "device_blocks", "fallback_blocks",
                  "host_bytes"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.parallel = self.parallel or other.parallel
        self.shards = max(self.shards, other.shards)
        self.calls += max(other.calls, 1)


class LZ4DecodeEngine:
    """Two-phase (plan/execute) frame decoder with pluggable block fan-out.

    >>> eng = LZ4DecodeEngine(workers=4, executor="process")
    >>> data = eng.decode(frame)             # blocks fan across the pool
    >>> data[a:b] == FrameReader(frame, engine=eng).read_range(a, b - a)
    True

    With ``executor="device"`` phase two runs in jit — one vmapped dispatch
    per micro-batch of stacked `DevicePlan`s — and `decode_to_device`
    returns the restored bytes as a device array without any host copy.
    """

    def __init__(self, workers: int | None = None, executor: str | None = None,
                 min_parallel_blocks: int = 2, two_phase: bool | None = None,
                 micro_batch: int = 8, use_pallas: bool = False,
                 caps: DevicePlanCaps | None = None,
                 adaptive_rounds: bool = True,
                 plan_on_device: bool = False,
                 on_error: str = "raise",
                 telemetry: bool | None = None,
                 mesh=None,
                 shard_axes: tuple[str, ...] | None = None):
        if executor is not None and executor not in _EXECUTORS:
            raise ValueError(f"executor must be one of {_EXECUTORS}")
        if on_error not in ("raise", "salvage"):
            raise ValueError('on_error must be "raise" or "salvage"')
        if plan_on_device and executor != "device":
            raise ValueError("plan_on_device requires executor='device'")
        if workers is not None and workers < 1:
            raise ValueError("workers must be >= 1")
        if micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        # Sharded-fabric configuration — the read-side mirror of
        # `LZ4Engine(mesh=...)`: with a mesh spanning >1 shard, frame-block
        # decode routes through `distributed.fabric.decode_items_sharded`
        # (host planning, then shard_map(vmap(decode_gather)) dispatches).
        if mesh is not None:
            axes = tuple(shard_axes) if shard_axes is not None \
                else tuple(mesh.axis_names)
            for a in axes:
                if a not in mesh.axis_names:
                    raise ValueError(f"shard axis {a!r} not in mesh "
                                     f"{tuple(mesh.axis_names)}")
            from repro.distributed.fabric import mesh_shard_count

            self.mesh, self.shard_axes = mesh, axes
            self.shards = mesh_shard_count(mesh, axes)
        else:
            if shard_axes is not None:
                raise ValueError("shard_axes requires mesh")
            self.mesh, self.shard_axes, self.shards = None, (), 1
        if executor is None:
            executor = "serial" if (workers or 1) == 1 else "thread"
        if workers is None:
            workers = 1 if executor in ("serial", "device") \
                else min(4, os.cpu_count() or 1)
        self.workers = workers
        self.executor = executor if (workers > 1 or executor == "device") \
            else "serial"
        self.min_parallel_blocks = min_parallel_blocks
        # Device-executor knobs (harmless elsewhere): blocks per vmapped
        # dispatch, kernel selection, fixed plan-array caps, and whether
        # host planning computes exact wave depths so shallow micro-batches
        # compile fewer pointer-doubling rounds (vs the static worst case).
        self.micro_batch = micro_batch
        self.use_pallas = use_pallas
        self.caps = caps or DevicePlanCaps()
        self.adaptive_rounds = adaptive_rounds
        # Speculative in-graph planning: parse the token stream ON DEVICE
        # (kernels/plan_speculative.py) and fuse plan+execute(+CRC) into
        # one dispatch per micro-batch — `plan_block_fast` runs only as the
        # per-block fallback for payloads/plans that overflow the caps.
        # `adaptive_rounds` has no effect on this path: with no host plan
        # there is no `n_waves`, so the resolve always compiles
        # MAX_RESOLVE_ROUNDS.
        self.plan_on_device = plan_on_device
        # Per-block strategy: the fused chunked decoder wins single-threaded
        # on CPython (one loop, no plan materialization), the two-phase
        # plan/execute decoder releases the GIL through its NumPy phases and
        # is the shape parallel/accelerator backends consume.  Auto: fused
        # inline, two-phase in workers.  Both are bit-identical (tested).
        self.two_phase = (self.executor != "serial") if two_phase is None \
            else two_phase
        # on_error="salvage": `decode` of a damaged frame falls back to the
        # salvage pass (repro.resilience.salvage) and returns everything
        # recoverable with lost blocks zero-filled — NEVER silently: the
        # fallback is counted (``resilience.*`` obs counters) and
        # `last_salvage` holds the full SalvageReport (hole map, per-block
        # errors).  Intact frames are byte-identical either way; "raise"
        # (the default) keeps strict all-or-nothing decode semantics.
        self.on_error = on_error
        self.last_salvage = None
        # Telemetry: None follows the global `repro.obs` gate at call time;
        # True/False pins this instance (never changes decoded bytes).
        self.telemetry = telemetry
        self.stats = DecodeStats()      # most recent call (see DecodeStats)
        self.totals = DecodeStats()     # lifetime accumulator
        # `totals` is shared mutable state: concurrent calls (FrameReader
        # users across threads, serving restore fan-out) each fold their
        # own per-call stats object in under this lock, so lifetime
        # counters never lose updates.  `stats` stays last-call-wins.
        self._totals_lock = threading.Lock()
        self._pool = None
        self._pool_lock = threading.Lock()

    def _obs_on(self) -> bool:
        return obs.enabled_for(self.telemetry)

    def _finish_call(self, st: DecodeStats) -> None:
        """Fold the finished call's stats into `totals` + the obs registry."""
        s = st
        s.calls = 1
        with self._totals_lock:
            self.totals.accumulate(s)
        if self._obs_on():
            r = obs.registry()
            r.counter("decode.calls", "decode calls").inc()
            r.counter("decode.blocks", "frame blocks decoded").inc(s.blocks)
            r.counter("decode.raw_blocks",
                      "raw-passthrough blocks").inc(s.raw_blocks)
            r.counter("decode.bytes_in", "compressed bytes in").inc(s.bytes_in)
            r.counter("decode.bytes_out", "decoded bytes out").inc(s.bytes_out)
            r.counter("decode.dispatches",
                      "device-executor jit dispatches").inc(s.dispatches)
            r.counter("decode.device_blocks",
                      "blocks decoded inside jit").inc(s.device_blocks)
            r.counter("decode.fallback_blocks",
                      "device-executor blocks decoded on host "
                      "(plan overflowed DevicePlanCaps)").inc(s.fallback_blocks)
            r.counter("decode.host_bytes",
                      "content bytes fetched device -> host").inc(s.host_bytes)

    # -- worker pool --------------------------------------------------------

    def _get_pool(self):
        with self._pool_lock:
            if self._pool is None:
                if self.executor == "process":
                    import multiprocessing as mp
                    from concurrent.futures import ProcessPoolExecutor

                    self._pool = ProcessPoolExecutor(
                        self.workers, mp_context=mp.get_context("fork"),
                    )
                else:
                    self._pool = ThreadPoolExecutor(
                        max_workers=self.workers,
                        thread_name_prefix="lz4-decode",
                    )
            return self._pool

    def close(self) -> None:
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _map(self, fn, items: list, st: DecodeStats) -> list:
        """Run fn over items on the configured executor (inline when the
        batch is too small for fan-out to pay)."""
        if (self.executor in ("thread", "process") and self.workers > 1
                and len(items) >= self.min_parallel_blocks):
            st.parallel = True
            # ~4 chunks per worker: amortizes the process pool's per-task
            # IPC (3x measured) while keeping the tail balanced.
            chunk = max(1, len(items) // (self.workers * 4))
            # list() so the first worker exception propagates to the caller.
            return list(self._get_pool().map(fn, items, chunksize=chunk))
        return [fn(it) for it in items]

    # -- single blocks ------------------------------------------------------

    def decode_block(self, payload: bytes, max_out: int | None = None) -> bytes:
        """Planned decode of one raw LZ4 block (no framing)."""
        return execute_plan(
            payload, plan_block_fast(payload, max_out=max_out)).tobytes()

    def decode_blocks(self, payloads: list[bytes], raws: list[bool],
                      usizes: list[int] | None = None) -> list[bytes]:
        """Decode a bag of independent blocks in parallel.

        ``raws[i]`` marks payloads stored uncompressed (returned as-is).
        ``usizes`` (optional) caps and checks each block's decoded size;
        without it blocks are capped at MAX_BLOCK.  This is the entry point
        for non-frame block stores (the checkpoint format keeps its own
        block index in manifest.json).
        """
        if len(payloads) != len(raws):
            raise ValueError("payloads/raws length mismatch")
        if usizes is not None and len(usizes) != len(payloads):
            raise ValueError("usizes length mismatch")
        st = DecodeStats(
            blocks=len(payloads), raw_blocks=sum(map(bool, raws)),
            bytes_in=sum(len(p) for p in payloads),
        )
        self.stats = st
        try:
            with obs.span_factory(self._obs_on())(
                    "decode.total", blocks=len(payloads),
                    executor=self.executor):
                return self._decode_blocks_inner(payloads, raws, usizes, st)
        finally:
            self._finish_call(st)

    def _decode_blocks_inner(self, payloads, raws, usizes,
                             st: DecodeStats) -> list[bytes]:
        ob = self._obs_on()
        out: list[bytes | None] = [None] * len(payloads)
        if self.mesh is not None and self.shards > 1:
            from repro.distributed import fabric

            st.shards = self.shards
            items = [(i, bytes(p),
                      usizes[i] if usizes is not None else None, None,
                      bool(raw))
                     for i, (p, raw) in enumerate(zip(payloads, raws))]
            out = fabric.decode_items_sharded(self, items, st)
            st.bytes_out = sum(len(d) for d in out)
            return out
        if self.executor == "device" and self.plan_on_device:
            self._decode_blocks_specplan(payloads, raws, usizes, out, st)
        elif self.executor == "device":
            jobs = []
            for i, (payload, raw) in enumerate(zip(payloads, raws)):
                payload = bytes(payload)
                if raw:
                    out[i] = payload
                    continue
                usize = usizes[i] if usizes is not None else None
                plan, dplan = self._plan_for_device(
                    payload, usize if usize is not None else MAX_BLOCK)
                if usize is not None and plan.usize != usize:
                    raise LZ4FormatError(
                        f"block {i}: decoded {plan.usize} bytes, "
                        f"expected {usize}"
                    )
                if dplan is None:
                    st.fallback_blocks += 1
                    out[i] = execute_plan(payload, plan).tobytes()
                else:
                    jobs.append((i, payload, dplan))

            def finish(slot, payload, dp, row):
                out[slot] = self._fetch_row(row, dp.out_size, st)

            self._execute_device(jobs, finish, st)
        else:
            jobs = []
            for i, (payload, raw) in enumerate(zip(payloads, raws)):
                if raw:
                    out[i] = bytes(payload)
                else:
                    jobs.append((i, (bytes(payload),
                                     usizes[i] if usizes is not None else None,
                                     i, self.two_phase, ob)))
            for (i, _), data in zip(jobs, self._map(_plain_block_task,
                                                    [j for _, j in jobs], st)):
                out[i] = data
        st.bytes_out = sum(len(d) for d in out)
        return out

    # -- device executor ----------------------------------------------------

    def _plan_for_device(self, payload: bytes, cap: int | None):
        """Host phase one for the device executor: plan, then convert to a
        fixed-shape DevicePlan.  Returns (plan, dplan-or-None); a None
        dplan means the plan overflowed the caps and this block must
        execute on host (the per-block fallback, counted by the caller)."""
        with obs.span_factory(self._obs_on())(
                "decode.plan", bytes_in=len(payload), executor="device"):
            plan = plan_block_fast(payload, max_out=cap)
            if len(payload) > self.caps.blk_cap:
                return plan, None
            try:
                return plan, to_device_plan(
                    plan, self.caps, compute_waves=self.adaptive_rounds)
            except DevicePlanOverflow:
                return plan, None

    def _dispatch_device(self, batch: list, st: DecodeStats):
        """ONE vmapped jit dispatch for a micro-batch of (payload, dplan).

        Pads the batch count to the next power of two (bounded compile
        shapes, like the compress engine) and buckets the pointer-doubling
        depth to a power of two; padding rows decode to out_size=0.
        """
        import jax.numpy as jnp

        sp = obs.span_factory(self._obs_on())
        caps = self.caps
        m = pad_pow2_count(len(batch), self.micro_batch)
        blk = np.zeros((m, caps.blk_cap), np.uint8)
        lit = [np.zeros((m, caps.max_lit), np.int32) for _ in range(3)]
        mat = [np.zeros((m, caps.max_match), np.int32) for _ in range(2)]
        scal = [np.zeros((m,), np.int32) for _ in range(3)]
        rounds = 0
        for j, (payload, dp) in enumerate(batch):
            blk[j, : len(payload)] = np.frombuffer(payload, np.uint8)
            lit[0][j], lit[1][j], lit[2][j] = dp.lit_src, dp.lit_dst, dp.lit_len
            mat[0][j], mat[1][j] = dp.match_dst, dp.match_off
            scal[0][j], scal[1][j], scal[2][j] = dp.n_lit, dp.n_match, dp.out_size
            rounds = max(rounds, dp.n_waves)
        fn = _device_decode_compiled(caps.out_cap, _round_bucket(rounds),
                                     self.use_pallas)
        st.dispatches += 1
        st.device_blocks += len(batch)
        with sp("decode.execute", rows=len(batch), executor="device",
                rounds=rounds):
            return fn(jnp.asarray(blk), *(jnp.asarray(a) for a in lit),
                      *(jnp.asarray(a) for a in mat),
                      *(jnp.asarray(a) for a in scal))

    def _execute_device(self, jobs: list, finish, st: DecodeStats) -> None:
        """Micro-batched, double-buffered device execution.

        ``jobs``: list of (slot, payload, dplan); ``finish(slot, payload,
        dplan, row)`` consumes one block's device output row (a jnp view of
        the padded output buffer) as each micro-batch drains.  Micro-batch
        i+1 is dispatched before batch i's rows are consumed, so host-side
        stacking overlaps device compute (jax dispatch is asynchronous).
        """
        inflight = None
        for start in range(0, len(jobs), self.micro_batch):
            chunk = jobs[start: start + self.micro_batch]
            res = self._dispatch_device([(p, dp) for _, p, dp in chunk], st)
            if inflight is not None:
                prev, out = inflight
                for row, (slot, payload, dp) in enumerate(prev):
                    finish(slot, payload, dp, out[row])
            inflight = (chunk, res)
        if inflight is not None:
            prev, out = inflight
            for row, (slot, payload, dp) in enumerate(prev):
                finish(slot, payload, dp, out[row])

    def _fetch_row(self, row, usize: int, st: DecodeStats) -> bytes:
        """Slice-fetch exactly `usize` decoded bytes of one output row
        (the transfer the host_bytes counter measures).  The span doubles
        as the device-wait measurement: the fetch synchronizes on the
        dispatched decode graph."""
        with obs.span_factory(self._obs_on())("decode.drain", bytes=usize):
            data = np.asarray(row[:usize]).tobytes()
        st.host_bytes += usize
        return data

    # -- device executor: speculative in-graph planning ---------------------

    def _dispatch_specplan(self, batch: list, st: DecodeStats,
                           compute_crc: bool):
        """ONE fused plan+decode jit dispatch for a micro-batch of raw
        (payload, max_out) pairs — the speculative twin of
        `_dispatch_device`, minus the host parse: payloads are stacked
        as-is and the device does header decode, chain select, validation,
        layout, resolve, and (optionally) CRC in a single graph.
        """
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        sp = obs.span_factory(self._obs_on())
        caps = self.caps
        m = pad_pow2_count(len(batch), self.micro_batch)
        blk = np.zeros((m, caps.blk_cap + kops.SPEC_PAD), np.uint8)
        ns = np.zeros((m,), np.int32)
        mo = np.zeros((m,), np.int32)
        for j, (payload, max_out) in enumerate(batch):
            blk[j, : len(payload)] = np.frombuffer(payload, np.uint8)
            ns[j] = len(payload)
            mo[j] = max_out
        fn = _device_plan_decode_compiled(caps.out_cap, caps.max_lit,
                                          caps.max_match, MAX_RESOLVE_ROUNDS,
                                          self.use_pallas, compute_crc)
        st.dispatches += 1
        with sp("decode.plan_device", rows=len(batch), executor="device",
                crc=compute_crc):
            return fn(jnp.asarray(blk), jnp.asarray(ns), jnp.asarray(mo))

    def _execute_specplan(self, jobs: list, finish, st: DecodeStats,
                          compute_crc: bool) -> None:
        """Micro-batched, double-buffered speculative execution.

        ``jobs``: list of (slot, payload, max_out); ``finish(slot, payload,
        stat, row, crc)`` consumes one block's host status vector (a
        (SPEC_STATUS,) np.int32 — fetching it synchronizes the dispatch,
        like `_fetch_row`; 20 bytes of metadata, uncounted by the content
        ledger `host_bytes`), decoded device row, and device CRC scalar.
        Batch i+1 is dispatched before batch i's statuses are fetched, so
        stacking overlaps device compute exactly like `_execute_device`.
        """
        def drain(chunk, res):
            out, status, crc = res
            stat = np.asarray(status)
            for row, (slot, payload, _max_out) in enumerate(chunk):
                finish(slot, payload, stat[row], out[row], crc[row])

        inflight = None
        for start in range(0, len(jobs), self.micro_batch):
            chunk = jobs[start: start + self.micro_batch]
            res = self._dispatch_specplan(
                [(p, mo) for _, p, mo in chunk], st, compute_crc)
            if inflight is not None:
                drain(*inflight)
            inflight = (chunk, res)
        if inflight is not None:
            drain(*inflight)

    def _decode_blocks_specplan(self, payloads, raws, usizes, out,
                                st: DecodeStats) -> None:
        """`decode_blocks` body for the speculative planner (fills `out`).

        Error parity with the host-planner branch: parse errors raise the
        planner's exact message unwrapped; a decoded-size mismatch against
        a caller-provided usize raises ``block {i}: decoded ... expected``.
        Payloads over `blk_cap` and plans over the fixed caps take the same
        counted host fallback.
        """
        jobs = []
        for i, (payload, raw) in enumerate(zip(payloads, raws)):
            payload = bytes(payload)
            if raw:
                out[i] = payload
                continue
            usize = usizes[i] if usizes is not None else None
            cap = usize if usize is not None else MAX_BLOCK
            if len(payload) > self.caps.blk_cap:
                st.fallback_blocks += 1
                plan = plan_block_fast(payload, max_out=cap)
                if usize is not None and plan.usize != usize:
                    raise LZ4FormatError(
                        f"block {i}: decoded {plan.usize} bytes, "
                        f"expected {usize}"
                    )
                out[i] = execute_plan(payload, plan).tobytes()
                continue
            jobs.append((i, payload, cap))

        from repro.kernels import ops as kops

        def finish(slot, payload, stat, row, _crc):
            err = int(stat[kops.SPEC_ERR])
            if err:
                raise LZ4FormatError(_spec_err_message(err))
            usize = usizes[slot] if usizes is not None else None
            if int(stat[kops.SPEC_OVERFLOW]):
                st.fallback_blocks += 1
                cap = usize if usize is not None else MAX_BLOCK
                plan = plan_block_fast(payload, max_out=cap)
                if usize is not None and plan.usize != usize:
                    raise LZ4FormatError(
                        f"block {slot}: decoded {plan.usize} bytes, "
                        f"expected {usize}"
                    )
                out[slot] = execute_plan(payload, plan).tobytes()
                return
            out_size = int(stat[kops.SPEC_OUT_SIZE])
            if usize is not None and out_size != usize:
                raise LZ4FormatError(
                    f"block {slot}: decoded {out_size} bytes, "
                    f"expected {usize}"
                )
            st.device_blocks += 1
            out[slot] = self._fetch_row(row, out_size, st)

        self._execute_specplan(jobs, finish, st, compute_crc=False)

    def _specplan_host_fallback(self, i: int, b: dict, payload: bytes,
                                to_device: bool, st: DecodeStats, sp):
        """Host plan+execute for one frame block the speculative path cannot
        keep on device — payload over `blk_cap`, or a valid plan that
        overflowed the fixed caps.  Same counted per-block fallback
        semantics as the host planner's `DevicePlanOverflow` path,
        including the plan-time size-vs-table parity check and the
        unconditional post-decode `check_block`."""
        st.fallback_blocks += 1
        try:
            with sp("decode.plan", bytes_in=len(payload), executor="device",
                    fallback=True):
                plan = plan_block_fast(payload, max_out=b["usize"])
        except FrameFormatError:
            raise
        except LZ4FormatError as e:
            raise FrameFormatError(f"block {i}: {e}") from e
        if plan.usize != b["usize"]:
            raise FrameFormatError(
                f"block {i}: decoded {plan.usize} bytes, "
                f"table says {b['usize']}"
            )
        with sp("decode.execute", block=i, fallback=True):
            data = execute_plan(payload, plan).tobytes()
        with sp("decode.verify", block=i):
            check_block(i, b["usize"], b["crc"], data)
        return self._host_result(data, to_device)

    def _decode_entries_specplan(self, frame: bytes,
                                 entries: list[tuple[int, dict]],
                                 to_device: bool = False, verify: bool = True,
                                 st: DecodeStats | None = None):
        """`_decode_entries_device` with speculative in-graph planning.

        The whole per-block pipeline — header parse, chain select,
        validation, layout, resolve, CRC — runs as one fused dispatch per
        micro-batch; the host touches only each block's (SPEC_STATUS,)
        status vector.  With ``to_device=True`` the decoded content never
        crosses device->host (the CRC comes from the same fused graph), so
        `DecodeStats.host_bytes` stays 0 INCLUDING planning.  Error parity
        with the host-planner path: parse errors raise
        ``block {i}: <planner message>``, size mismatches raise the
        ``table says`` message, caps overflows take the counted host
        fallback.
        """
        if st is None:
            st = self.stats
        from repro.kernels import ops as kops

        sp = obs.span_factory(self._obs_on())
        meta = {}
        out: list = [None] * len(entries)
        jobs = []
        pending_crc: list[tuple[int, object, int]] = []
        for j, (i, b) in enumerate(entries):
            payload = frame[b["offset"]: b["offset"] + b["csize"]]
            if b["raw"]:
                with sp("decode.verify", block=i, raw=True):
                    check_block(i, b["usize"], b["crc"], payload)
                out[j] = self._host_result(payload, to_device)
                continue
            if len(payload) > self.caps.blk_cap:
                out[j] = self._specplan_host_fallback(
                    i, b, payload, to_device, st, sp)
                continue
            meta[j] = (i, b)
            jobs.append((j, payload, b["usize"]))

        def finish(slot, payload, stat, row, crc):
            i, b = meta[slot]
            err = int(stat[kops.SPEC_ERR])
            if err:
                raise FrameFormatError(f"block {i}: {_spec_err_message(err)}")
            if int(stat[kops.SPEC_OVERFLOW]):
                out[slot] = self._specplan_host_fallback(
                    i, b, payload, to_device, st, sp)
                return
            out_size = int(stat[kops.SPEC_OUT_SIZE])
            if out_size != b["usize"]:
                raise FrameFormatError(
                    f"block {i}: decoded {out_size} bytes, "
                    f"table says {b['usize']}"
                )
            st.device_blocks += 1
            if to_device:
                # The in-graph CRC scalar rides the fused dispatch; the
                # host compare is DEFERRED so it never stalls the drain.
                if verify and b["crc"] is not None:
                    pending_crc.append((i, crc, b["crc"]))
                out[slot] = row[:out_size]
                return
            data = self._fetch_row(row, out_size, st)
            with sp("decode.verify", block=i):
                check_block(i, b["usize"], b["crc"], data)
            out[slot] = data

        self._execute_specplan(jobs, finish, st,
                               compute_crc=bool(to_device and verify))
        with sp("decode.verify", blocks=len(pending_crc), in_graph=True):
            for i, got, want in pending_crc:
                if int(got) != want:
                    raise FrameFormatError(f"block {i}: checksum mismatch")
        return out

    # -- frames -------------------------------------------------------------

    def _decode_entries(self, frame: bytes, entries: list[tuple[int, dict]],
                        st: DecodeStats | None = None) -> list[bytes]:
        """Decode the given (index, table-entry) frame blocks, in order.

        ``st`` is the owning call's stats object; `FrameReader` reads come
        through without one and count into whatever call came last
        (documented in `DecodeStats`).
        """
        if st is None:
            st = self.stats
        if self.mesh is not None and self.shards > 1:
            from repro.distributed import fabric

            st.shards = self.shards
            items = [(i, frame[b["offset"]: b["offset"] + b["csize"]],
                      b["usize"], b["crc"], b["raw"]) for i, b in entries]
            return fabric.decode_items_sharded(self, items, st)
        if self.executor == "device":
            return self._decode_entries_device(frame, entries, st=st)
        ob = self._obs_on()
        sp = obs.span_factory(ob)
        out: list[bytes | None] = [None] * len(entries)
        jobs = []
        for j, (i, b) in enumerate(entries):
            payload = frame[b["offset"]: b["offset"] + b["csize"]]
            if b["raw"]:
                with sp("decode.verify", block=i, raw=True):
                    check_block(i, b["usize"], b["crc"], payload)
                out[j] = payload
            else:
                jobs.append((j, (payload, b["usize"], b["crc"], i,
                                 self.two_phase, ob)))
        for (j, _), data in zip(jobs, self._map(_frame_block_task,
                                                [a for _, a in jobs], st)):
            out[j] = data
        return out

    def _decode_entries_device(self, frame: bytes,
                               entries: list[tuple[int, dict]],
                               to_device: bool = False, verify: bool = True,
                               st: DecodeStats | None = None):
        """Device-executor decode of (index, table-entry) frame blocks.

        ``to_device=True`` returns per-block DEVICE arrays (uint8) instead
        of host bytes — and the content NEVER crosses the device->host
        boundary: with ``verify=True`` each block's CRC32 is computed
        in-graph (slice-by-8, `kernels.ops.crc32_bytes`) and only the
        4-byte checksum is fetched for comparison against the table
        (raw/fallback blocks are uploaded host->device;
        `DecodeStats.host_bytes` stays the download-only *content* counter,
        mirroring `EngineStats`, so verified device restores keep it at 0).
        """
        if st is None:
            st = self.stats
        if self.plan_on_device:
            return self._decode_entries_specplan(
                frame, entries, to_device=to_device, verify=verify, st=st)
        if to_device and verify:
            from repro.kernels.ops import crc32_bytes  # already jitted

        sp = obs.span_factory(self._obs_on())
        meta = {}
        out: list = [None] * len(entries)
        jobs = []
        pending_crc: list[tuple[int, object, int]] = []
        for j, (i, b) in enumerate(entries):
            payload = frame[b["offset"]: b["offset"] + b["csize"]]
            if b["raw"]:
                with sp("decode.verify", block=i, raw=True):
                    check_block(i, b["usize"], b["crc"], payload)
                out[j] = self._host_result(payload, to_device)
                continue
            try:
                plan, dplan = self._plan_for_device(payload, b["usize"])
            except FrameFormatError:
                raise
            except LZ4FormatError as e:
                raise FrameFormatError(f"block {i}: {e}") from e
            # Size-vs-table parity with the host paths, for free at plan
            # time: the plan knows the exact decoded size before dispatch,
            # so a lying table entry is rejected even when ``verify=False``
            # skips the post-decode check_block (which would need a fetch).
            if plan.usize != b["usize"]:
                raise FrameFormatError(
                    f"block {i}: decoded {plan.usize} bytes, "
                    f"table says {b['usize']}"
                )
            if dplan is None:
                st.fallback_blocks += 1
                with sp("decode.execute", block=i, fallback=True):
                    data = execute_plan(payload, plan).tobytes()
                with sp("decode.verify", block=i):
                    check_block(i, b["usize"], b["crc"], data)
                out[j] = self._host_result(data, to_device)
                continue
            meta[j] = (i, b)
            jobs.append((j, payload, dplan))

        def finish(slot, payload, dp, row):
            i, b = meta[slot]
            dev = row[: dp.out_size]
            if to_device:
                # Size-vs-table parity was enforced at plan time; the CRC
                # check runs in-graph so the content stays device-resident
                # (only the 4-byte checksum comes home, uncounted by the
                # content ledger `host_bytes`).  The checksum dispatch is
                # asynchronous and the host compare is DEFERRED below, so
                # verification never stalls the double-buffered drain.
                if verify and b["crc"] is not None:
                    pending_crc.append((i, crc32_bytes(row, dp.out_size),
                                        b["crc"]))
                out[slot] = dev
                return
            data = self._fetch_row(row, dp.out_size, st)
            with sp("decode.verify", block=i):
                check_block(i, b["usize"], b["crc"], data)
            out[slot] = data

        self._execute_device(jobs, finish, st)
        with sp("decode.verify", blocks=len(pending_crc), in_graph=True):
            for i, got, want in pending_crc:
                if int(got) != want:
                    raise FrameFormatError(f"block {i}: checksum mismatch")
        return out

    @staticmethod
    def _host_result(data: bytes, to_device: bool):
        if not to_device:
            return data
        import jax.numpy as jnp

        return jnp.asarray(np.frombuffer(data, np.uint8))

    def salvage(self, frame: bytes):
        """Salvage pass over a (possibly damaged) frame: decode every
        undamaged block on this engine's executor, reconstruct what v6
        parity can prove byte-identical, and return the `SalvageReport`
        (recovered data with holes zero-filled + exact loss accounting).
        See repro/resilience/salvage.py."""
        from repro.resilience.salvage import salvage_frame

        report = salvage_frame(frame, engine=self)
        self.last_salvage = report
        return report

    def decode(self, frame: bytes) -> bytes:
        """Frame -> original bytes; bit-identical to `decode_frame_serial`.

        Raises FrameFormatError on any malformation, including per-block
        checksum mismatches on version-2 frames — unless constructed with
        ``on_error="salvage"``, which turns a failed strict decode into a
        salvage pass returning everything recoverable (lost blocks
        zero-filled; the full accounting lands in ``last_salvage``).
        """
        if self.on_error == "salvage":
            try:
                return self._decode_strict(frame)
            except FrameFormatError:
                return self.salvage(frame).data
        return self._decode_strict(frame)

    def _decode_strict(self, frame: bytes) -> bytes:
        info = frame_info(frame)
        blocks = info["blocks"]
        st = DecodeStats(
            blocks=len(blocks),
            raw_blocks=sum(b["raw"] for b in blocks),
            bytes_in=len(frame),
        )
        self.stats = st
        try:
            with obs.span_factory(self._obs_on())(
                    "decode.total", blocks=len(blocks),
                    executor=self.executor):
                parts = self._decode_entries(frame, list(enumerate(blocks)),
                                             st)
                out = b"".join(parts)
                # v5 whole-object trailer: per-block CRCs already passed,
                # this catches join-order/table-swap corruption they can't.
                check_content_crc(info["content_crc"], block_crc(out))
            st.bytes_out = len(out)
            return out
        finally:
            self._finish_call(st)

    def decode_to_device(self, frame: bytes, verify: bool = True):
        """Frame -> decoded bytes as ONE device uint8 array (no host copy).

        The accelerator-to-accelerator restore path: compressed blocks are
        uploaded, decoded in-graph, and concatenated on device, so a
        KV-offload restore never materializes the plaintext on the host.
        ``verify=True`` (default) checks each block's CRC32 *on device*
        (slice-by-8 table walk in-graph, `kernels.ops.crc32_bytes`) and
        fetches only the 4-byte checksum for comparison — verified
        restores keep `host_bytes` at 0 too; ``verify=False`` skips even
        that scalar sync (the frame table's structural validation and the
        host planner's format checks always run).

        Works on any engine instance (it always uses the device execution
        path, regardless of `executor`).
        """
        import jax.numpy as jnp

        info = frame_info(frame)
        blocks = info["blocks"]
        st = DecodeStats(
            blocks=len(blocks),
            raw_blocks=sum(b["raw"] for b in blocks),
            bytes_in=len(frame),
        )
        self.stats = st
        try:
            sp = obs.span_factory(self._obs_on())
            with sp("decode.total", blocks=len(blocks), executor="device",
                    to_device=True, verify=verify):
                parts = self._decode_entries_device(
                    frame, list(enumerate(blocks)), to_device=True,
                    verify=verify, st=st)
                if not parts:
                    out = jnp.zeros((0,), jnp.uint8)
                else:
                    out = parts[0] if len(parts) == 1 \
                        else jnp.concatenate(parts)
                if verify and info["content_crc"] is not None:
                    # v5 whole-object trailer, checked IN-GRAPH over the
                    # concatenated device array (pow2-padded so compiled
                    # shapes stay bounded); like per-block verification,
                    # only the 4-byte checksum crosses to host.
                    from repro.kernels.ops import crc32_bytes

                    total = int(out.shape[0])
                    cap = 1 if total == 0 else 1 << (total - 1).bit_length()
                    padded = out if cap == total else jnp.concatenate(
                        [out, jnp.zeros((cap - total,), jnp.uint8)])
                    with sp("decode.verify", content=True, in_graph=True):
                        crc = int(crc32_bytes(padded, total))
                    check_content_crc(info["content_crc"], crc)
            st.bytes_out = sum(b["usize"] for b in blocks)
            return out
        finally:
            self._finish_call(st)


class FrameReader:
    """Seekable random-access reader over one frame.

    The frame's block table is the seek index: cumulative block usizes map
    decompressed offsets to blocks, so `read_range` touches only the blocks
    covering the requested range and `read_block` exactly one.  Decoded
    blocks pass through a small LRU (``cache_blocks``) so clustered reads —
    a KV-offload restore walking one request's slice, a data-pipeline batch
    re-reading the same shard region — decode each block once.

    >>> r = FrameReader(frame)
    >>> r.read_range(10, 20) == original[10:30]
    True
    """

    def __init__(self, frame: bytes, engine: LZ4DecodeEngine | None = None,
                 cache_blocks: int = 8, on_error: str = "raise"):
        if on_error not in ("raise", "salvage"):
            raise ValueError('on_error must be "raise" or "salvage"')
        self._frame = bytes(frame)
        self._engine = engine or default_decode_engine()
        if on_error == "salvage":
            # Tolerant table parse: a reader over a damaged frame still
            # exposes every readable entry (reads of blocks whose payloads
            # are damaged fail per-block; `salvage()` has the recovery).
            from .frame import scan_frame

            self._info = scan_frame(self._frame)
        else:
            self._info = frame_info(self._frame)
        self.on_error = on_error
        self._blocks = self._info["blocks"]
        # starts[i] = decompressed offset of block i; starts[-1] = total size.
        self._starts = np.concatenate(
            ([0], np.cumsum([b["usize"] for b in self._blocks]))
        ).astype(np.int64)
        self._cache_blocks = cache_blocks
        self._cache: OrderedDict[int, bytes] = OrderedDict()
        self._cache_lock = threading.Lock()

    # -- index --------------------------------------------------------------

    @property
    def block_count(self) -> int:
        # len(blocks), not the header count: a salvage-mode reader over a
        # truncated table exposes only the entries it could read.
        return len(self._blocks)

    @property
    def usize(self) -> int:
        """Total decompressed size (from the table; no payload touched)."""
        return int(self._starts[-1])

    def __len__(self) -> int:
        return self.usize

    def block_range(self, i: int) -> tuple[int, int]:
        """Decompressed [start, end) interval of block i."""
        if not 0 <= i < self.block_count:
            raise IndexError(f"block {i} out of range [0, {self.block_count})")
        return int(self._starts[i]), int(self._starts[i + 1])

    def blocks_for_range(self, start: int, length: int) -> range:
        """Indices of the blocks covering decompressed [start, start+length)."""
        if start < 0 or length < 0 or start + length > self.usize:
            raise ValueError(
                f"range [{start}, {start + length}) outside [0, {self.usize})"
            )
        if length == 0:
            return range(0, 0)
        lo = int(np.searchsorted(self._starts, start, side="right")) - 1
        hi = int(np.searchsorted(self._starts, start + length, side="left"))
        return range(lo, hi)

    # -- reads --------------------------------------------------------------

    def _cache_put(self, i: int, data: bytes) -> None:
        if self._cache_blocks <= 0:
            return
        with self._cache_lock:
            self._cache[i] = data
            self._cache.move_to_end(i)
            while len(self._cache) > self._cache_blocks:
                self._cache.popitem(last=False)

    def read_block(self, i: int) -> bytes:
        """Decode (or raw-slice) exactly block i, LRU-cached."""
        self.block_range(i)  # bounds check
        with self._cache_lock:
            if i in self._cache:
                self._cache.move_to_end(i)
                return self._cache[i]
        data = self._engine._decode_entries(
            self._frame, [(i, self._blocks[i])]
        )[0]
        self._cache_put(i, data)
        return data

    def read_range(self, start: int, length: int) -> bytes:
        """original[start : start+length], decoding only the covering blocks.

        Blocks already in the LRU are reused; only the missing ones are
        decoded (in one engine call, so parallel executors still fan out),
        and those land in the LRU for the next clustered read.
        """
        cover = self.blocks_for_range(start, length)
        if len(cover) == 0:
            return b""
        have: dict[int, bytes] = {}
        with self._cache_lock:
            for i in cover:
                if i in self._cache:
                    self._cache.move_to_end(i)
                    have[i] = self._cache[i]
        missing = [i for i in cover if i not in have]
        if missing:
            for i, data in zip(missing, self._engine._decode_entries(
                    self._frame, [(i, self._blocks[i]) for i in missing])):
                have[i] = data
                self._cache_put(i, data)
        joined = have[cover[0]] if len(cover) == 1 else \
            b"".join(have[i] for i in cover)
        base = int(self._starts[cover[0]])
        return joined[start - base: start - base + length]

    def read_range_device(self, start: int, length: int, verify: bool = True):
        """`read_range`, but the result is a DEVICE uint8 array.

        Covering blocks are decoded in-graph (`_decode_entries_device`) and
        concatenated + sliced on device, so a KV-offload restore of one
        request's slice never lands on the host — including its CRC check,
        which runs in-graph (``verify=False`` skips even the checksum
        sync; see `LZ4DecodeEngine.decode_to_device`).  Bypasses the
        host-bytes LRU — device buffers are the accelerator's to cache.
        """
        import jax.numpy as jnp

        cover = self.blocks_for_range(start, length)
        if len(cover) == 0:
            return jnp.zeros((0,), jnp.uint8)
        parts = self._engine._decode_entries_device(
            self._frame, [(i, self._blocks[i]) for i in cover],
            to_device=True, verify=verify)
        joined = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        base = int(self._starts[cover[0]])
        return joined[start - base: start - base + length]

    def read(self) -> bytes:
        """Full decode (parallel over all blocks)."""
        return self._engine.decode(self._frame)

    def salvage(self):
        """Salvage pass over this reader's frame — decode every undamaged
        block, reconstruct from v6 parity where provable, and return the
        `SalvageReport` (repro/resilience/salvage.py).  Works regardless
        of ``on_error`` (a strict reader can still salvage after a read
        raised)."""
        return self._engine.salvage(self._frame)
