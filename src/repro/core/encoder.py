"""Exact LZ4 block-format encoder (sequence plan -> bytes)."""
from __future__ import annotations

import numpy as np

from .lz4_types import MIN_MATCH, Sequence, plan_coverage


def encode_block(data: bytes | np.ndarray, sequences: list[Sequence]) -> bytes:
    """Emit the LZ4 block for a sequence plan produced by any scheme."""
    buf = bytes(data) if not isinstance(data, bytes) else data
    if plan_coverage(sequences) != len(buf):
        raise ValueError("plan does not cover the block exactly")
    out = bytearray()
    for i, seq in enumerate(sequences):
        is_last = i == len(sequences) - 1
        if is_last and seq.match_len:
            raise ValueError("last sequence must be literals-only")
        if not is_last and not seq.match_len:
            raise ValueError("interior sequence missing a match")
        lit = seq.lit_len
        ml = seq.match_len - MIN_MATCH if seq.match_len else 0
        token = (min(lit, 15) << 4) | min(ml, 15)
        out.append(token)
        if lit >= 15:
            rem = lit - 15
            while rem >= 255:
                out.append(255)
                rem -= 255
            out.append(rem)
        out += buf[seq.lit_start : seq.lit_start + seq.lit_len]
        if seq.match_len:
            out.append(seq.offset & 0xFF)
            out.append((seq.offset >> 8) & 0xFF)
            if ml >= 15:
                rem = ml - 15
                while rem >= 255:
                    out.append(255)
                    rem -= 255
                out.append(rem)
    return bytes(out)
