"""Batched, device-resident compression pipeline: the `LZ4Engine`.

The engine is the primary write-path API (`compress_bytes`, the original
entry point, survives only as a deprecated wrapper).  It keeps the paper's
feedback-free token pipeline batch-parallel end to end:

  * arbitrary-length input is split into a ``(B, MAX_BLOCK + _PAD)`` uint8
    stack and compressed with ONE vmapped+jitted dispatch per micro-batch
    (configurable ``micro_batch``, donated input buffers);
  * dispatch is double-buffered: while the device crunches micro-batch i,
    the host pads and dispatches micro-batch i+1, so padding/transfer —
    and, with ``device_emit``, the host-side frame assembly of the previous
    micro-batch — overlaps device compute;
  * byte emission is device-resident by default (``device_emit=True``): the
    jit graph computes token byte-lengths, exclusive prefix-sum offsets,
    and the byte scatter (`jax_compressor.compress_block_bytes` ->
    `kernels.ops.emit_bytes`), so only final frame bytes cross the host
    boundary, once per micro-batch.  ``device_emit=False`` fetches the
    per-window match records instead and emits on host with the vectorized
    prefix-sum emitter (emitter.py) — the bit-identity oracle path;
  * output is a self-describing frame (frame.py, spec in
    docs/frame-format.md) with per-block sizes, CRC32s, and a
    raw-passthrough flag for uncompressible blocks, decodable by
    `decode_frame` with no out-of-band metadata.

`EngineStats.host_bytes` counts every byte fetched from the device, so the
host-transfer saving of ``device_emit`` is directly observable
(benchmarks/engine_batched.py records it; trade-offs in docs/tuning.md).

Partial trailing micro-batches are padded up to the next power of two (capped
at ``micro_batch``) so the number of compiled shapes is bounded by
log2(micro_batch) + 1 rather than one per input length.

See docs/architecture.md for the stage-by-stage map of the write path onto
the paper's hardware pipeline.
"""
from __future__ import annotations

import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .emitter import emit_block
from .frame import block_crc, decode_frame, encode_frame
from .jax_compressor import (
    _PAD,
    compress_block_bytes,
    compress_block_records,
    resolve_candidate_impl,
)
from .lz4_types import (
    DEFAULT_HASH_BITS,
    DEFAULT_MAX_MATCH,
    DEFAULT_PWS,
    MAX_BLOCK,
    pad_pow2_count,
)

__all__ = ["LZ4Engine", "EngineStats", "default_engine"]


@functools.lru_cache(maxsize=1)
def default_engine() -> "LZ4Engine":
    """Process-wide default engine (shared by serving offload, checkpointing)."""
    return LZ4Engine()


@functools.lru_cache(maxsize=None)
def _batched_compiled(hash_bits, max_match, pws, use_pallas, scan_impl,
                      candidate_impl, donate, device_emit):
    """Jitted vmap of the single-block kernel, cached per static config.

    Module-level cache so every LZ4Engine instance (and the compress_bytes
    wrapper) shares compilations; jit's own cache then keys on batch shape.
    ``device_emit`` selects the fused compress+emit graph (bytes out) over
    the records-only graph (match records out, emitted on host).
    """
    base = compress_block_bytes if device_emit else compress_block_records
    fn = functools.partial(
        base,
        hash_bits=hash_bits, max_match=max_match, pws=pws,
        use_pallas=use_pallas, scan_impl=scan_impl,
        candidate_impl=candidate_impl,
    )
    kw = {"donate_argnums": (0,)} if donate else {}
    return jax.jit(jax.vmap(fn), **kw)


@dataclasses.dataclass
class EngineStats:
    """Per-call counters (PLUS a lifetime accumulator on the engine).

    ``engine.stats`` is replaced at the start of every `compress` /
    `compress_to_blocks` call — it describes the MOST RECENT call only.
    ``engine.totals`` is the cumulative sum over the engine's lifetime
    (merged in as each call finishes, even on error); use it — or the
    ``engine.*`` counters in `repro.obs.registry()` when telemetry is on —
    for anything that must survive across calls.
    """

    blocks: int = 0
    dispatches: int = 0
    raw_blocks: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    host_bytes: int = 0  # bytes fetched device -> host (records or emit buffers)
    candidate_impl: str = ""  # the RESOLVED impl that ran ("auto" never runs)
    shards: int = 0  # sharded-fabric calls: shard count of the v4 container
    calls: int = 0  # 1 per finished call (so totals.calls counts calls)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def accumulate(self, other: "EngineStats") -> None:
        """Fold ``other`` (one finished call) into this accumulator.

        NOT thread-safe by itself — the engine serializes its `totals`
        accumulation behind a lock (`_finish_call`); external accumulators
        shared across threads need their own.
        """
        for f in ("blocks", "dispatches", "raw_blocks", "bytes_in",
                  "bytes_out", "host_bytes"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.calls += max(other.calls, 1)
        self.shards = max(self.shards, other.shards)
        if other.candidate_impl:
            self.candidate_impl = other.candidate_impl


def _slice_payload(out: np.ndarray, j: int, size: int) -> bytes:
    """Row j's first `size` bytes of a drained (M, out_cap) emit buffer."""
    return out[j, :size].tobytes()


class LZ4Engine:
    """Batched LZ4 compression engine (the paper's combined scheme, S1+S2).

    >>> eng = LZ4Engine()
    >>> frame = eng.compress(data)          # one dispatch per micro-batch
    >>> assert eng.decompress(frame) == data
    """

    def __init__(self, hash_bits: int = DEFAULT_HASH_BITS,
                 max_match: int = DEFAULT_MAX_MATCH,
                 pws: int = DEFAULT_PWS,
                 micro_batch: int = 32,
                 use_pallas: bool = False,
                 scan_impl: str = "sequential",
                 candidate_impl: str = "auto",
                 donate: bool | None = None,
                 device_emit: bool = True,
                 drain: str = "sliced",
                 content_crc: bool = False,
                 parity_group: int | None = None,
                 telemetry: bool | None = None,
                 mesh=None,
                 shard_axes: tuple[str, ...] | None = None,
                 shards: int | None = None):
        if micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        if drain not in ("sliced", "full"):
            raise ValueError('drain must be "sliced" or "full"')
        # Sharded-fabric configuration (docs/architecture.md §Sharded
        # compression fabric).  ``mesh`` routes `compress` through
        # shard_map over ``shard_axes`` (default: every mesh axis) and the
        # output becomes a frame-v4 container; ``shards`` without a mesh
        # selects the host-partition path (the per-shard oracle, and the
        # only option on a single-device container) writing the same v4
        # shape.  ``shards=None`` with no mesh keeps the classic v3 writer.
        if mesh is not None:
            axes = tuple(shard_axes) if shard_axes is not None \
                else tuple(mesh.axis_names)
            for a in axes:
                if a not in mesh.axis_names:
                    raise ValueError(f"shard axis {a!r} not in mesh "
                                     f"{tuple(mesh.axis_names)}")
            from repro.distributed.fabric import mesh_shard_count

            n = mesh_shard_count(mesh, axes)
            if shards is not None and shards != n:
                raise ValueError(f"shards={shards} != mesh shard count {n}")
            if not device_emit and n > 1:
                raise ValueError(
                    "the mesh fabric path requires device_emit=True "
                    "(host emission cannot run under shard_map)")
            self.mesh, self.shard_axes, self.shards = mesh, axes, n
        else:
            if shard_axes is not None:
                raise ValueError("shard_axes requires mesh")
            if shards is not None and shards < 1:
                raise ValueError("shards must be >= 1")
            self.mesh, self.shard_axes, self.shards = None, (), shards
        self.hash_bits = hash_bits
        self.max_match = max_match
        self.pws = pws
        self.micro_batch = micro_batch
        self.use_pallas = use_pallas
        self.scan_impl = scan_impl
        # "auto" resolves ONCE, here, to the best impl for the active
        # backend (sortkey on CPU — measured; scatter on GPU and on TPU
        # without Pallas; fused on TPU with use_pallas) — the dispatch and
        # the jit cache only ever see a concrete impl name, and
        # EngineStats.candidate_impl records what actually ran.
        self.candidate_impl = resolve_candidate_impl(candidate_impl,
                                                     use_pallas=use_pallas)
        # Donation only pays (and only avoids a warning) off-CPU.
        self.donate = (jax.default_backend() != "cpu") if donate is None else donate
        # device_emit=True: byte emission stays in the jit graph; only the
        # final bytes cross the host boundary.  False: fetch match records
        # and emit on host via emit_block (the bit-identity oracle path).
        self.device_emit = device_emit
        # drain="sliced" (device_emit only): two-step fetch — size scalars
        # first, then exactly `size` bytes per block, and NOTHING for
        # blocks bound for raw passthrough — so host_bytes is the exact
        # compressed payload.  "full" fetches the whole padded (M, out_cap)
        # buffer per micro-batch in one transfer (fewer, larger copies; the
        # pre-two-step behaviour, kept measurable in benchmarks).
        self.drain = drain
        # content_crc=True: stamp a whole-object CRC32 trailer on every
        # frame (version 5) on top of the per-block checksums — full-frame
        # decoders verify the JOINED output too (frame.py docstring has the
        # failure modes per-block checks cannot see).  Default off: the v3
        # (or v4, sharded) writer stays byte-identical.
        self.content_crc = content_crc
        # parity_group=N: append one XOR parity block per N data blocks so
        # salvage (repro.resilience) can reconstruct any SINGLE damaged
        # block per group byte-identically — the frame becomes version 6,
        # which always carries the whole-content trailer too (the v6 writer
        # implies content_crc).  Default off: frame bytes are untouched.
        if parity_group is not None and parity_group < 1:
            raise ValueError("parity_group must be >= 1")
        self.parity_group = parity_group
        # Telemetry: None follows the global `repro.obs` gate (REPRO_OBS /
        # obs.configure) at CALL time; True/False pins this instance.  The
        # resolved flag never changes frame bytes — it only decides whether
        # spans/metrics are recorded (tested byte-identical either way).
        self.telemetry = telemetry
        self.stats = EngineStats()      # most recent call (see EngineStats)
        self.totals = EngineStats()     # lifetime accumulator
        # `totals` is shared mutable state: concurrent calls (serving
        # offload threads all using default_engine()) each fold their own
        # per-call stats object in under this lock, so lifetime counters
        # never lose updates.  `stats` stays a last-call-wins pointer.
        self._totals_lock = threading.Lock()
        self._sp = obs.span_factory(False)  # refreshed per call
        self._worker: "LZ4Engine | None" = None  # fabric host-path clone

    def _obs_on(self) -> bool:
        return obs.enabled_for(self.telemetry)

    def _shard_worker(self) -> "LZ4Engine":
        """Single-device clone for the fabric's host-partition path (same
        datapath config, no mesh — the per-shard oracle)."""
        if self._worker is None:
            self._worker = LZ4Engine(
                hash_bits=self.hash_bits, max_match=self.max_match,
                pws=self.pws, micro_batch=self.micro_batch,
                use_pallas=self.use_pallas, scan_impl=self.scan_impl,
                candidate_impl=self.candidate_impl, donate=self.donate,
                device_emit=self.device_emit, drain=self.drain,
                telemetry=self.telemetry,
            )
        return self._worker

    def _finish_call(self, st: EngineStats) -> None:
        """Fold the finished call's stats into `totals` + the obs registry."""
        s = st
        s.calls = 1
        with self._totals_lock:
            self.totals.accumulate(s)
        if self._obs_on():
            r = obs.registry()
            r.counter("engine.calls", "compress calls").inc()
            r.counter("engine.blocks", "64 KB blocks compressed").inc(s.blocks)
            r.counter("engine.raw_blocks",
                      "blocks stored as raw passthrough").inc(s.raw_blocks)
            r.counter("engine.dispatches", "jit dispatches").inc(s.dispatches)
            r.counter("engine.bytes_in", "input bytes").inc(s.bytes_in)
            r.counter("engine.bytes_out", "frame bytes out").inc(s.bytes_out)
            r.counter("engine.host_bytes",
                      "bytes fetched device -> host").inc(s.host_bytes)

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self, stack: np.ndarray, ns: np.ndarray, st: EngineStats):
        """ONE device dispatch for a (M, MAX_BLOCK+_PAD) micro-batch."""
        fn = _batched_compiled(
            self.hash_bits, self.max_match, self.pws, self.use_pallas,
            self.scan_impl, self.candidate_impl, self.donate,
            self.device_emit,
        )
        st.dispatches += 1
        with self._sp("compress.dispatch", rows=len(ns),
                      impl=self.candidate_impl):
            return fn(jnp.asarray(stack), jnp.asarray(ns))

    def _pad_batch(self, chunks: list[bytes]) -> tuple[np.ndarray, np.ndarray]:
        """Stack chunks into a fixed-shape micro-batch (padded rows get n=0)."""
        with self._sp("compress.pad", blocks=len(chunks)):
            m = pad_pow2_count(len(chunks), self.micro_batch)
            stack = np.zeros((m, MAX_BLOCK + _PAD), np.uint8)
            ns = np.zeros((m,), np.int32)
            for j, c in enumerate(chunks):
                stack[j, : len(c)] = np.frombuffer(c, np.uint8)
                ns[j] = len(c)
            return stack, ns

    def _payload_iter(self, data: bytes, st: EngineStats):
        """Yield (chunk, n, size, payload_fn) per block, counting into `st`.

        `payload_fn()` materializes the compressed block bytes: a buffer
        slice on the device-emit path, a host `emit_block` call otherwise.
        Double-buffered: micro-batch i+1 is padded and dispatched before the
        host blocks on micro-batch i's results, so host-side padding (and
        frame assembly) overlaps device compute (jax dispatch is
        asynchronous).  ``st`` is the CALL-LOCAL stats object (incremented,
        never replaced) — concurrent calls each carry their own, which is
        what keeps `totals` exact under threaded use.
        """
        chunks = [data[i: i + MAX_BLOCK] for i in range(0, len(data), MAX_BLOCK)]
        st.blocks += len(chunks)
        st.bytes_in += len(data)
        ob = self._obs_on()
        self._sp = obs.span_factory(ob)
        occupancy = obs.registry().gauge(
            "engine.inflight_batches",
            "micro-batches dispatched but not yet drained (double buffer)",
        ) if ob else obs.NOOP_METRIC
        inflight = None
        for start in range(0, len(chunks), self.micro_batch):
            batch = chunks[start: start + self.micro_batch]
            stack, ns = self._pad_batch(batch)
            res = self._dispatch(stack, ns, st)
            occupancy.inc()
            if inflight is not None:
                # Double-buffer overlap: batch i drains while i+1 computes.
                if ob:
                    obs.registry().counter(
                        "engine.overlapped_dispatches",
                        "dispatches issued while the previous batch was "
                        "still in flight").inc()
                yield from self._drain(*inflight, st)
                occupancy.dec()
            inflight = (batch, res)
        if inflight is not None:
            yield from self._drain(*inflight, st)
            occupancy.dec()

    def _fetch_sliced(self, out_dev, j: int, size: int, st: EngineStats) -> bytes:
        """Slice-fetch exactly `size` compressed bytes of row j (the device
        slice executes on-device; only the payload crosses to host)."""
        with self._sp("compress.drain", bytes=size):
            data = np.asarray(out_dev[j, :size]).tobytes()
        st.host_bytes += size
        return data

    def _drain(self, batch: list[bytes], res, st: EngineStats):
        if self.device_emit:
            if self.drain == "sliced":
                # Two-step drain: sync on the tiny size vector, then fetch
                # exactly size[j] bytes per block — lazily, so blocks the
                # caller stores as raw passthrough (size >= n) never fetch
                # their emit buffer at all.
                out_dev, size_dev = res
                # The device_get is the sync point: its span measures how
                # long the host WAITS on device compute (the rest of the
                # drain is host-side transfer/assembly).
                with self._sp("compress.wait", rows=len(batch)):
                    size = jax.device_get(size_dev)
                st.host_bytes += size.nbytes
                for j, chunk in enumerate(batch):
                    s = int(size[j])
                    yield chunk, len(chunk), s, functools.partial(
                        self._fetch_sliced, out_dev, j, s, st)
                return
            with self._sp("compress.wait", rows=len(batch)):
                out, size = jax.device_get(res)
            st.host_bytes += out.nbytes + size.nbytes
            for j, chunk in enumerate(batch):
                s = int(size[j])
                yield chunk, len(chunk), s, functools.partial(_slice_payload, out, j, s)
        else:
            with self._sp("compress.wait", rows=len(batch)):
                emit, pos, length, offset, size = jax.device_get(
                    (res.emit, res.pos, res.length, res.offset, res.size)
                )
            st.host_bytes += (emit.nbytes + pos.nbytes + length.nbytes
                              + offset.nbytes + size.nbytes)
            for j, chunk in enumerate(batch):
                yield chunk, len(chunk), int(size[j]), functools.partial(
                    emit_block, chunk, emit[j], pos[j], length[j], offset[j],
                    len(chunk),
                )

    # -- public API ---------------------------------------------------------

    def compress(self, data: bytes) -> bytes:
        """bytes -> self-describing frame (see frame.py / docs/frame-format.md).

        Blocks whose exact compressed size (computed in-graph) does not beat
        the raw size are stored as raw passthrough, so worst-case expansion
        is the frame header, not LZ4's literal-run overhead.  With a mesh or
        ``shards=`` configured the call routes through the sharded fabric
        (distributed/fabric.py) and the output is a frame-v4 container.
        """
        st = EngineStats(candidate_impl=self.candidate_impl)
        self.stats = st
        ob = self._obs_on()
        sp = obs.span_factory(ob)
        if self.shards is not None:
            from repro.distributed import fabric

            try:
                with sp("compress.total", bytes_in=len(data),
                        shards=self.shards):
                    return fabric.compress_sharded(self, data, st)
            finally:
                self._finish_call(st)
        ratio_hist = obs.registry().histogram(
            "engine.block_ratio", obs.DEFAULT_RATIO_BUCKETS,
            "per-block compression ratio usize/csize (raw blocks -> 1.0)",
        ) if ob else None
        try:
            with sp("compress.total", bytes_in=len(data)):
                payloads, usizes, raws, crcs = [], [], [], []
                for chunk, n, size, payload_fn in self._payload_iter(data, st):
                    if size >= n:
                        payloads.append(chunk)
                        raws.append(True)
                        st.raw_blocks += 1
                        if ratio_hist is not None and n:
                            ratio_hist.observe(1.0)
                    else:
                        payloads.append(payload_fn())
                        raws.append(False)
                        if ratio_hist is not None and size:
                            ratio_hist.observe(n / size)
                    usizes.append(n)
                    # Content checksum over the ORIGINAL chunk (only the
                    # compressor ever sees it): makes the frame a version-2,
                    # integrity-checked container — decode verifies per block.
                    crcs.append(block_crc(chunk))
                with sp("compress.frame", blocks=len(payloads)):
                    frame = encode_frame(
                        payloads, usizes, raws, checksums=crcs,
                        content_crc=block_crc(data)
                        if (self.content_crc or self.parity_group is not None)
                        else None,
                        parity_group=self.parity_group)
                st.bytes_out = len(frame)
                return frame
        finally:
            self._finish_call(st)

    def compress_to_blocks(self, data: bytes) -> list[bytes]:
        """bytes -> list of raw LZ4 blocks (one per 64 KB, no framing).

        Backwards-compatible output of the old `compress_bytes`: every block
        is valid LZ4 (no passthrough), lengths must travel out-of-band.
        Sharded engines partition the block stack across shards (same
        contiguous split as `compress`) but the output is the same flat,
        globally-ordered block list.
        """
        st = EngineStats(candidate_impl=self.candidate_impl)
        self.stats = st
        if not data:
            # Host-emitted empty block: no dispatch, no candidate stage ran.
            st.blocks = 1
            self._finish_call(st)
            return [emit_block(b"", [], [], [], [], 0)]
        if self.shards is not None:
            from repro.distributed import fabric

            try:
                with obs.span_factory(self._obs_on())(
                        "compress.total", bytes_in=len(data), framing=False,
                        shards=self.shards):
                    blocks = fabric.shard_blocks_sharded(self, data, st)
                st.bytes_out = sum(len(b) for b in blocks)
                return blocks
            finally:
                self._finish_call(st)
        try:
            with obs.span_factory(self._obs_on())(
                    "compress.total", bytes_in=len(data), framing=False):
                blocks = [payload_fn() for _, _, _, payload_fn
                          in self._payload_iter(data, st)]
            st.bytes_out = sum(len(b) for b in blocks)
            return blocks
        finally:
            self._finish_call(st)

    def decompress(self, frame: bytes) -> bytes:
        """Inverse of `compress`; validates the frame (sizes + checksums)
        throughout.  Delegates to the parallel `LZ4DecodeEngine`."""
        return decode_frame(frame)
