"""Core LZ4 compression library — the paper's contribution.

Public API:
    LZ4Engine            — batched device-resident pipeline (frame in/out)
    compress_greedy      — software baseline (GitHub-like, multi-match, unbounded)
    compress_windowed    — the paper's single-match / bounded scheme (golden model)
    encode_block / decode_block — exact LZ4 block format round trip
    emit_block           — vectorized (prefix-sum) block emission
    encode_frame / decode_frame — self-describing multi-block container
"""
from .lz4_types import (  # noqa: F401
    DEFAULT_HASH_BITS,
    DEFAULT_MAX_MATCH,
    DEFAULT_PWS,
    MAX_BLOCK,
    Sequence,
    plan_coverage,
    plan_size,
)
from .reference import compress_greedy, compression_ratio  # noqa: F401
from .schemes import compress_windowed, compress_windowed_multi  # noqa: F401
from .encoder import encode_block  # noqa: F401
from .decoder import decode_block, decode_block_bytewise, LZ4FormatError  # noqa: F401
from .emitter import emit_block, emit_block_from_records  # noqa: F401
from .frame import (  # noqa: F401
    FrameFormatError,
    decode_frame,
    encode_frame,
    frame_info,
)
from .engine import LZ4Engine  # noqa: F401
from .corpus import corpus_blocks, corpus_files  # noqa: F401
