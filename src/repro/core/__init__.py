"""Core LZ4 compression library — the paper's contribution.

The primary APIs are the two engines (`LZ4Engine` in, `LZ4DecodeEngine`
out); everything else is a building block or a bit-identity oracle for one
of their stages.  docs/architecture.md maps each stage to the paper's
hardware pipeline and to these modules.

Public API:
    LZ4Engine            — batched compression pipeline (frame in/out); with
                           ``device_emit=True`` (default) byte emission stays
                           in the jit graph and only final frame bytes cross
                           the host boundary
    LZ4DecodeEngine      — parallel two-phase (plan/execute) frame decoder;
                           ``executor="device"`` runs plan execution inside
                           the jit graph (fixed-shape DevicePlans, pointer-
                           doubling source resolve) and `decode_to_device`
                           restores straight into device memory
    FrameReader          — seekable random access over a frame's block table
                           (`read_range_device` keeps the bytes on device)
    default_engine       — process-wide shared LZ4Engine
    compress_greedy      — software baseline (GitHub-like, multi-match, unbounded)
    compress_windowed    — the paper's single-match / bounded scheme (golden model)
    encode_block / decode_block — exact LZ4 block format round trip
    plan_block / execute_plan   — two-phase block decode building blocks
    DevicePlan / to_device_plan — fixed-shape (jit-stackable) form of a
                           BlockPlan; `execute_device_plan` is the NumPy
                           twin of the on-device decode algorithm
    emit_block           — host-side vectorized (prefix-sum) block emission:
                           the engine's ``device_emit=False`` path and the
                           oracle for the device emitter
    encode_frame / decode_frame — self-describing multi-block container
                           (byte-level spec: docs/frame-format.md)
    decode_frame_serial  — serial block-walk oracle for the decode engine
"""
from .lz4_types import (  # noqa: F401
    DEFAULT_HASH_BITS,
    DEFAULT_MAX_MATCH,
    DEFAULT_PWS,
    MAX_BLOCK,
    Sequence,
    plan_coverage,
    plan_size,
)
from .reference import compress_greedy, compression_ratio  # noqa: F401
from .schemes import compress_windowed, compress_windowed_multi  # noqa: F401
from .encoder import encode_block  # noqa: F401
from .decoder import decode_block, decode_block_bytewise, LZ4FormatError  # noqa: F401
from .emitter import emit_block, emit_block_from_records  # noqa: F401
from .frame import (  # noqa: F401
    VERSION_V1,
    VERSION_V2,
    VERSION_V3,
    VERSION_V4,
    VERSION_V5,
    VERSION_V6,
    FrameFormatError,
    block_crc,
    check_content_crc,
    decode_frame,
    decode_frame_serial,
    encode_frame,
    frame_info,
    parity_group_blocks,
    scan_frame,
    xor_bytes,
)
from .decode_plan import (  # noqa: F401
    BlockPlan,
    DevicePlan,
    DevicePlanCaps,
    DevicePlanOverflow,
    decode_block_planned,
    execute_device_plan,
    execute_plan,
    plan_block,
    plan_block_fast,
    to_device_plan,
)
from .decode_engine import (  # noqa: F401
    DecodeStats,
    FrameReader,
    LZ4DecodeEngine,
    default_decode_engine,
)
from .engine import EngineStats, LZ4Engine, default_engine  # noqa: F401
from .jax_compressor import (  # noqa: F401
    CANDIDATE_IMPLS,
    resolve_candidate_impl,
)
from .corpus import corpus_blocks, corpus_files  # noqa: F401
