"""Hardware cycle/throughput model reproducing the paper's Table IV analysis.

The paper's scheme is *deterministic*: one parallelization window per clock
cycle regardless of data (the whole point of restrictions S1+S2), so

    cycles(ours)      = n_windows + PIPELINE_DEPTH
    throughput(ours)  = PWS bytes x f_clk              (16.10 Gb/s @ 251.57 MHz)

The multi-match/unbounded baselines ([10] FIFO, [11] window advance) lose
cycles to (a) each additional match recovered inside a window and (b) each
feedback-loop trip of the unbounded extended-match stage:

    cycles(baseline)  = sum_w max(1, matches_w + extension_reads_w)

which reproduces the ~30-40 % parallelism loss the paper reports (6.4->4.5,
10->6.08 Gb/s).  Frequencies are taken from the published implementations —
they cannot be measured here (no FPGA); see DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .lz4_types import DEFAULT_PWS
from .schemes import MultiMatchResult

PIPELINE_DEPTH = 12  # fill latency of the feedforward pipeline; amortized over a block

# Published clock frequencies (paper Table IV).
FREQ_OURS_MHZ = 251.57
FREQ_BENES_MHZ = 156.25   # [10] — feedback loop limits frequency


@dataclasses.dataclass(frozen=True)
class Throughput:
    cycles: int
    bytes_in: int
    bytes_per_cycle: float
    gbps_at: dict[str, float]  # label -> Gb/s at that frequency


def ours_cycles(n_bytes: int, pws: int = DEFAULT_PWS) -> int:
    return -(-n_bytes // pws) + PIPELINE_DEPTH


def ours_throughput(n_bytes: int, pws: int = DEFAULT_PWS) -> Throughput:
    cycles = ours_cycles(n_bytes, pws)
    bpc = n_bytes / cycles
    return Throughput(
        cycles=cycles,
        bytes_in=n_bytes,
        bytes_per_cycle=bpc,
        gbps_at={
            f"{FREQ_OURS_MHZ}MHz": bpc * FREQ_OURS_MHZ * 1e6 * 8 / 1e9,
        },
    )


def baseline_cycles(result: MultiMatchResult, n_bytes: int, pws: int = DEFAULT_PWS) -> int:
    """Cycle count for the multi-match FIFO baseline on actual data."""
    per_window = np.maximum(1, result.matches_per_window + result.extension_reads)
    return int(per_window.sum()) + PIPELINE_DEPTH


def baseline_throughput(result: MultiMatchResult, n_bytes: int, pws: int = DEFAULT_PWS) -> Throughput:
    cycles = baseline_cycles(result, n_bytes, pws)
    bpc = n_bytes / cycles
    return Throughput(
        cycles=cycles,
        bytes_in=n_bytes,
        bytes_per_cycle=bpc,
        gbps_at={
            f"{FREQ_BENES_MHZ}MHz": bpc * FREQ_BENES_MHZ * 1e6 * 8 / 1e9,
        },
    )


def peak_gbps(pws: int = DEFAULT_PWS, mhz: float = FREQ_OURS_MHZ) -> float:
    """Theoretical peak: PWS bytes/cycle at f_clk."""
    return pws * mhz * 1e6 * 8 / 1e9
