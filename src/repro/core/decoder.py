"""Independent LZ4 block decoder, written against the public block-format spec.

Used as the round-trip oracle: every compressor in this repo must produce
blocks this decoder restores bit-exactly.  Deliberately shares no code with
the encoder.
"""
from __future__ import annotations


class LZ4FormatError(ValueError):
    pass


def decode_block(block: bytes, max_out: int | None = None) -> bytes:
    out = bytearray()
    i = 0
    n = len(block)
    while True:
        if i >= n:
            raise LZ4FormatError("truncated block: missing token")
        token = block[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated literal length")
                b = block[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise LZ4FormatError("truncated literals")
        out += block[i : i + lit_len]
        i += lit_len
        if i == n:
            break  # final literals-only sequence
        if i + 2 > n:
            raise LZ4FormatError("truncated offset")
        offset = block[i] | (block[i + 1] << 8)
        i += 2
        if offset == 0:
            raise LZ4FormatError("zero offset")
        if offset > len(out):
            raise LZ4FormatError("offset beyond output")
        match_len = (token & 0xF) + 4
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated match length")
                b = block[i]
                i += 1
                match_len += b
                if b != 255:
                    break
        # Byte-by-byte copy: overlapping matches (offset < match_len) replicate.
        src = len(out) - offset
        for j in range(match_len):
            out.append(out[src + j])
        if max_out is not None and len(out) > max_out:
            raise LZ4FormatError("output exceeds limit")
    return bytes(out)
