"""Independent LZ4 block decoder, written against the public block-format spec.

Used as the round-trip oracle: every compressor in this repo must produce
blocks this decoder restores bit-exactly.  Deliberately shares no code with
the encoder.

Two implementations with identical semantics:

  decode_block           — fast path: literals and non-overlapping matches
                           copy as whole slices; overlapping matches
                           (offset < match_len) replicate their offset-wide
                           pattern in chunks instead of byte-by-byte.
  decode_block_bytewise  — the original byte-at-a-time reference, kept as the
                           oracle (tests assert equality on overlapping-match
                           blocks, where chunking is easiest to get wrong).
"""
from __future__ import annotations

from repro.resilience.errors import FrameError


class LZ4FormatError(FrameError, ValueError):
    """Malformed LZ4 block (parse/truncation/size errors).

    ValueError for backwards compatibility; `FrameError` for the unified
    corruption hierarchy (structured ``block_index``/``cause`` attributes
    — see repro/resilience/errors.py)."""


def decode_block(block: bytes, max_out: int | None = None) -> bytes:
    out = bytearray()
    i = 0
    n = len(block)
    while True:
        if i >= n:
            raise LZ4FormatError("truncated block: missing token")
        token = block[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated literal length")
                b = block[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise LZ4FormatError("truncated literals")
        # Cap BEFORE appending: a lying length field must not be able to
        # allocate past max_out (checking after the copy lets a crafted
        # block overshoot by an arbitrary run, and a final literals-only
        # sequence used to skip the check entirely).
        if max_out is not None and len(out) + lit_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        out += block[i : i + lit_len]
        i += lit_len
        if i == n:
            break  # final literals-only sequence
        if i + 2 > n:
            raise LZ4FormatError("truncated offset")
        offset = block[i] | (block[i + 1] << 8)
        i += 2
        if offset == 0:
            raise LZ4FormatError("zero offset")
        if offset > len(out):
            raise LZ4FormatError("offset beyond output")
        match_len = (token & 0xF) + 4
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated match length")
                b = block[i]
                i += 1
                match_len += b
                if b != 255:
                    break
        if max_out is not None and len(out) + match_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        src = len(out) - offset
        if offset >= match_len:
            # Non-overlapping: one chunked copy.
            out += out[src : src + match_len]
        else:
            # Overlapping: the copy replicates the trailing `offset`-byte
            # pattern cyclically; tiling it is equivalent to the byte loop.
            pattern = bytes(out[src:])
            reps = -(-match_len // offset)
            out += (pattern * reps)[:match_len]
    return bytes(out)


def decode_block_bytewise(block: bytes, max_out: int | None = None) -> bytes:
    """Byte-at-a-time reference decoder (oracle for the chunked fast path)."""
    out = bytearray()
    i = 0
    n = len(block)
    while True:
        if i >= n:
            raise LZ4FormatError("truncated block: missing token")
        token = block[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated literal length")
                b = block[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise LZ4FormatError("truncated literals")
        if max_out is not None and len(out) + lit_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        out += block[i : i + lit_len]
        i += lit_len
        if i == n:
            break  # final literals-only sequence
        if i + 2 > n:
            raise LZ4FormatError("truncated offset")
        offset = block[i] | (block[i + 1] << 8)
        i += 2
        if offset == 0:
            raise LZ4FormatError("zero offset")
        if offset > len(out):
            raise LZ4FormatError("offset beyond output")
        match_len = (token & 0xF) + 4
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated match length")
                b = block[i]
                i += 1
                match_len += b
                if b != 255:
                    break
        if max_out is not None and len(out) + match_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        # Byte-by-byte copy: overlapping matches (offset < match_len) replicate.
        src = len(out) - offset
        for j in range(match_len):
            out.append(out[src + j])
    return bytes(out)
