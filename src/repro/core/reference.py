"""Greedy software LZ4 — the "GitHub [15]" baseline of the paper (Tables I/III).

This is the multi-match, unbounded-extension compressor: it scans byte by byte,
emits every non-overlapping match it finds, and extends matches as far as the
data allows.  ``max_match`` caps the match length (paper Table II rows).

Implementation notes
--------------------
* Hash insertion is *dense* (every position, including inside matches), matching
  the paper's hardware which updates PWS table records every cycle.  With dense
  insertion, the table lookup for position ``p`` is exactly "the latest previous
  position with the same hash value", which we precompute vectorized (numpy)
  instead of simulating the table sequentially.  This keeps the golden model
  fast enough to sweep hash-table sizes over a ~MB corpus.
* All LZ4 end-of-block rules are enforced (see lz4_types).
"""
from __future__ import annotations

import numpy as np

from .lz4_types import (
    HASH_PRIME,
    LAST_LITERALS,
    MAX_BLOCK,
    MF_LIMIT,
    MIN_MATCH,
    Sequence,
)


def le32_words(data: np.ndarray) -> np.ndarray:
    """Little-endian uint32 word starting at each position (len-3 entries)."""
    d = data.astype(np.uint32)
    n = len(d)
    if n < 4:
        return np.zeros(0, dtype=np.uint32)
    return d[: n - 3] | (d[1 : n - 2] << 8) | (d[2 : n - 1] << 16) | (d[3:] << 24)


def fib_hash(words: np.ndarray, hash_bits: int) -> np.ndarray:
    """Fibonacci hash: (w * 2654435761) >> (32 - hash_bits)."""
    h = (words * np.uint32(HASH_PRIME)) & np.uint32(0xFFFFFFFF)
    return (h >> np.uint32(32 - hash_bits)).astype(np.int64)


def prev_same_hash(hashes: np.ndarray) -> np.ndarray:
    """For each position p: the largest q < p with hashes[q] == hashes[p], else -1.

    Vectorized predecessor query: stable argsort by hash groups equal hashes into
    runs ordered by position; the predecessor is simply the previous element of
    the run.
    """
    n = len(hashes)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.argsort(hashes, kind="stable")  # stable => ascending position in runs
    h_sorted = hashes[order]
    prev = np.full(n, -1, dtype=np.int64)
    same = h_sorted[1:] == h_sorted[:-1]
    prev[1:][same] = order[:-1][same]
    out = np.full(n, -1, dtype=np.int64)
    out[order] = prev
    return out


def match_length(data: np.ndarray, p: int, q: int, limit: int) -> int:
    """Length of the common prefix of data[p:] and data[q:], capped at `limit`."""
    a = data[p : p + limit]
    b = data[q : q + limit]
    m = min(len(a), len(b))
    neq = np.nonzero(a[:m] != b[:m])[0]
    return int(neq[0]) if len(neq) else m


def compress_greedy(
    data: bytes | np.ndarray,
    hash_bits: int = 12,
    max_match: int | None = None,
) -> list[Sequence]:
    """Greedy LZ4 sequence plan (multi-match, optionally length-capped).

    Returns the sequence plan; use encoder.encode_block for exact bytes or
    lz4_types.plan_size for the exact compressed size.
    """
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if not isinstance(data, np.ndarray) else data
    n = len(buf)
    if n > MAX_BLOCK:
        raise ValueError(f"block too large: {n} > {MAX_BLOCK}")
    sequences: list[Sequence] = []
    if n == 0:
        return [Sequence(0, 0)]
    words = le32_words(buf)
    hashes = fib_hash(words, hash_bits)
    cand = prev_same_hash(hashes)
    words_l = words  # uint32 view for O(1) word compare

    anchor = 0
    ip = 0
    limit_ip = n - MF_LIMIT  # last allowed match start (inclusive)
    while ip <= limit_ip and ip < len(words):
        q = cand[ip]
        if q >= 0 and words_l[q] == words_l[ip]:
            cap = n - LAST_LITERALS - ip
            if max_match is not None:
                cap = min(cap, max_match)
            if cap >= MIN_MATCH:
                mlen = MIN_MATCH + match_length(buf, ip + MIN_MATCH, int(q) + MIN_MATCH, cap - MIN_MATCH)
                sequences.append(Sequence(anchor, ip - anchor, mlen, ip - int(q)))
                ip += mlen
                anchor = ip
                continue
        ip += 1
    sequences.append(Sequence(anchor, n - anchor))
    return sequences


def compression_ratio(original_size: int, compressed_size: int) -> float:
    return original_size / compressed_size
