"""Vectorized HOST-side LZ4 block emission: per-window match records -> bytes.

This is the engine's ``device_emit=False`` path and the bit-identity ORACLE
for the device-resident emitter (`kernels.ops.emit_bytes`, the engine's
default), which computes the same bytes inside the jit graph so they never
round-trip through host NumPy at all (docs/architecture.md §write path).

Historically this module replaced `encode_block`'s Python loops — one
iteration per sequence plus one per length-extension byte, ~55 ms per
compressible 64 KB block — with NumPy prefix sums, GPULZ-style
(arXiv 2304.07342): the byte offset of every token, literal run, offset field
and extension-byte run is a cumulative sum over per-sequence sizes, so the
whole block materializes with a handful of fancy-indexed assignments (~3 ms).

The oracle chain is therefore:  `encode_block` (Python loops, most obviously
correct)  ==  `emit_block` (this module)  ==  device emit (in-graph).
tests/test_frame.py asserts the first equality on the property corpus;
tests/test_device_emit.py asserts the second, plus the engine-level frame
equality of ``device_emit=True|False``.
"""
from __future__ import annotations

import numpy as np

from .lz4_types import MIN_MATCH

__all__ = ["emit_block", "emit_block_from_records"]


def _ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenated ``[s, s+1, ..., s+c-1]`` for each (start, count) pair.

    The standard vectorized-ragged-range trick: one arange over the total
    length, rebased per segment via repeat of the segment starts.
    """
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    ends = np.cumsum(counts)
    rebase = np.repeat(starts.astype(np.int64) - (ends - counts), counts)
    return np.arange(total, dtype=np.int64) + rebase


def _ext_counts(values: np.ndarray) -> np.ndarray:
    """Length-extension byte count for token-nibble values >= 15."""
    return np.where(values < 15, 0, 1 + (values - 15) // 255).astype(np.int64)


def _fill_ext(out: np.ndarray, starts: np.ndarray, counts: np.ndarray,
              values: np.ndarray) -> None:
    """Write extension-byte runs: (count-1) bytes of 255, then (v-15) % 255."""
    sel = counts > 0
    if not sel.any():
        return
    s, c, v = starts[sel], counts[sel], values[sel]
    out[_ranges(s, c)] = 255
    out[s + c - 1] = (v - 15) % 255


def emit_block(data, emit, pos, length, offset, n: int) -> bytes:
    """Emit the LZ4 block for one set of per-window match records.

    data   : bytes or uint8 array holding at least the first `n` input bytes
    emit   : (W,) bool   — window emits a match
    pos    : (W,) int    — match start position (valid where emit)
    length : (W,) int    — match length (valid where emit)
    offset : (W,) int    — match back-offset (valid where emit)
    n      : true block length
    """
    buf = np.frombuffer(data, np.uint8, count=n) if isinstance(data, (bytes, bytearray, memoryview)) \
        else np.asarray(data, np.uint8)[:n]
    emit = np.asarray(emit, bool)
    w = np.nonzero(emit)[0]
    mpos = np.asarray(pos, np.int64)[w]
    mlen = np.asarray(length, np.int64)[w]
    moff = np.asarray(offset, np.int64)[w]

    # Anchors: each match's literals start where the previous match ended.
    ends = mpos + mlen
    anchors = np.concatenate([np.zeros(1, np.int64), ends[:-1]])
    lit = mpos - anchors
    ml = mlen - MIN_MATCH
    final_anchor = int(ends[-1]) if len(w) else 0
    final_lit = n - final_anchor

    lit_ext = _ext_counts(lit)
    match_ext = _ext_counts(ml)
    seq_sizes = 1 + lit_ext + lit + 2 + match_ext
    starts = np.concatenate([np.zeros(1, np.int64), np.cumsum(seq_sizes)])
    final_start = int(starts[-1])
    starts = starts[:-1]
    final_ext = int(_ext_counts(np.asarray([final_lit]))[0])
    total = final_start + 1 + final_ext + final_lit

    out = np.empty(total, np.uint8)
    # Tokens.
    out[starts] = (np.minimum(lit, 15) << 4) | np.minimum(ml, 15)
    # Literal-length extension bytes.
    _fill_ext(out, starts + 1, lit_ext, lit)
    # Literal runs (gather from input, scatter to output).
    lit_dst = starts + 1 + lit_ext
    out[_ranges(lit_dst, lit)] = buf[_ranges(anchors, lit)]
    # 16-bit little-endian offsets.
    off_at = lit_dst + lit
    out[off_at] = moff & 0xFF
    out[off_at + 1] = moff >> 8
    # Match-length extension bytes.
    _fill_ext(out, off_at + 2, match_ext, ml)
    # Final literals-only sequence.
    out[final_start] = min(final_lit, 15) << 4
    _fill_ext(out, np.asarray([final_start + 1]), np.asarray([final_ext]),
              np.asarray([final_lit]))
    out[final_start + 1 + final_ext:] = buf[final_anchor:n]
    return out.tobytes()


def emit_block_from_records(data, rec, n: int) -> bytes:
    """Convenience wrapper taking a BlockRecords (device or host arrays)."""
    return emit_block(
        data, np.asarray(rec.emit), np.asarray(rec.pos),
        np.asarray(rec.length), np.asarray(rec.offset), n,
    )
