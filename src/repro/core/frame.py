"""Self-describing multi-block container (LZ4-frame-style) with a seek index.

The normative byte-level specification of this format — complete enough for
a third party to implement an independent reader — lives in
docs/frame-format.md; this docstring is the working summary.

The raw block format needs out-of-band lengths: a list of compressed blocks
is not decodable without knowing where each block ends and how large it was
uncompressed.  This container makes `LZ4Engine.compress` output a single
self-describing byte string:

    frame  := magic(4) | version(1) | block_count(u32 LE)
              [content_size(u64 LE)]                          (version 3)
              | table | payloads
    table  := block_count x entry
    entry  := usize(u32 LE) | csize_flag(u32 LE)              (version 1)
            | usize(u32 LE) | csize_flag(u32 LE) | crc32(u32) (versions 2, 3)

`csize_flag` holds the payload size in the low 31 bits; the high bit marks an
uncompressible block stored raw (payload == original bytes, csize == usize).
Payloads are concatenated in block order immediately after the table.
Version 2 adds a CRC32 of each block's *uncompressed* content, so any stored
corruption — including a flipped literal byte that still parses — is detected
at decode time instead of surfacing as silent wrong output.  Version 3 (the
current writer default) additionally records the TOTAL content size in the
header; `frame_info` cross-checks it against the block table's usize sum, so
a corrupted table (or header) is rejected before any payload is decoded and
readers can size output buffers from the header alone.

The block table is a public seek index (Rapidgzip-style, arXiv 2308.08955):
blocks are compressed independently, `frame_info` exposes each block's
`usize`/`csize`/payload `offset` without touching payload bytes, and the
cumulative sum of `usize` maps any decompressed byte range to the covering
blocks.  `FrameReader.read_range` (decode_engine.py) uses exactly this to
decode only the blocks a partial read needs; consumers may likewise seek by
indexing the table directly.

Kept deliberately minimal otherwise (no dictionaries, no entropy stage): the
point is self-description, seekability, and the raw-passthrough escape hatch
the paper's hardware also needs for incompressible inputs.

Decoding entry points:

  decode_frame         — delegates to the parallel two-phase
                         `LZ4DecodeEngine` (decode_engine.py).
  decode_frame_serial  — the original serial block walk, kept as the oracle
                         (`bytewise=True` drops to the byte-at-a-time block
                         decoder for a fully independent reference).
"""
from __future__ import annotations

import binascii
import struct

from .decoder import LZ4FormatError, decode_block, decode_block_bytewise
from .lz4_types import MAX_BLOCK

MAGIC = b"LZ4R"
VERSION_V1 = 1
VERSION_V2 = 2
VERSION_V3 = 3
VERSION = VERSION_V3  # current writer version (checksums + content size)
RAW_FLAG = 0x80000000
_HEADER = struct.Struct("<4sBI")
_CONTENT_SIZE = struct.Struct("<Q")  # v3: total uncompressed size
_ENTRY_V1 = struct.Struct("<II")
_ENTRY_V2 = struct.Struct("<III")  # also the v3 entry


class FrameFormatError(LZ4FormatError):
    """Malformed frame: bad magic/version, truncation, lying size fields,
    or (version >= 2) a block checksum mismatch."""


def block_crc(data: bytes) -> int:
    """The frame's per-block checksum: CRC32 of the uncompressed content."""
    return binascii.crc32(data) & 0xFFFFFFFF


def encode_frame(payloads: list[bytes], usizes: list[int],
                 raw_flags: list[bool],
                 checksums: list[int] | None = None,
                 content_size: bool = True) -> bytes:
    """Assemble a frame from per-block payloads.

    payloads  : compressed block bytes (or raw input bytes where flagged)
    usizes    : uncompressed size of each block
    raw_flags : True where the payload is stored raw (uncompressible block)
    checksums : optional per-block `block_crc` of the UNCOMPRESSED content;
                when given the frame is written as version 3 (verified on
                decode), otherwise as version 1 (no integrity check).
    content_size : write the total uncompressed size into the header
                (version 3; requires checksums).  ``False`` produces a
                version-2 frame, byte-identical to the pre-v3 writer.
    """
    if not (len(payloads) == len(usizes) == len(raw_flags)):
        raise ValueError("payloads/usizes/raw_flags length mismatch")
    if checksums is not None and len(checksums) != len(payloads):
        raise ValueError("checksums length mismatch")
    if checksums is None:
        version = VERSION_V1
    else:
        version = VERSION_V3 if content_size else VERSION_V2
    parts = [_HEADER.pack(MAGIC, version, len(payloads))]
    if version == VERSION_V3:
        parts.append(_CONTENT_SIZE.pack(sum(usizes)))
    for i, (payload, usize, raw) in enumerate(zip(payloads, usizes, raw_flags)):
        if not 0 <= usize <= MAX_BLOCK:
            raise ValueError(f"block uncompressed size {usize} out of range")
        if raw and len(payload) != usize:
            raise ValueError("raw block payload must equal its usize")
        if len(payload) >= RAW_FLAG:
            raise ValueError("block payload too large")
        cf = len(payload) | (RAW_FLAG if raw else 0)
        if checksums is None:
            parts.append(_ENTRY_V1.pack(usize, cf))
        else:
            parts.append(_ENTRY_V2.pack(usize, cf, checksums[i] & 0xFFFFFFFF))
    parts.extend(bytes(p) for p in payloads)
    return b"".join(parts)


def frame_info(frame: bytes) -> dict:
    """Parse and validate the header/table; returns block metadata.

    Raises FrameFormatError without touching any payload bytes.  Each block
    dict carries the seek-index fields: `usize`, `csize`, `raw`, payload
    `offset` into the frame, and `crc` (None for version-1 frames).  The
    result's `content_size` is the version-3 header total (None for older
    versions), already validated against the table's usize sum — so a
    corrupted table or header field is caught BEFORE any payload decode.
    """
    if len(frame) < _HEADER.size:
        raise FrameFormatError("truncated frame header")
    magic, version, count = _HEADER.unpack_from(frame, 0)
    if magic != MAGIC:
        raise FrameFormatError(f"bad magic {magic!r}")
    if version not in (VERSION_V1, VERSION_V2, VERSION_V3):
        raise FrameFormatError(f"unsupported frame version {version}")
    table_start = _HEADER.size
    content_size = None
    if version == VERSION_V3:
        if len(frame) < table_start + _CONTENT_SIZE.size:
            raise FrameFormatError("truncated content-size header")
        (content_size,) = _CONTENT_SIZE.unpack_from(frame, table_start)
        table_start += _CONTENT_SIZE.size
    entry = _ENTRY_V1 if version == VERSION_V1 else _ENTRY_V2
    table_end = table_start + count * entry.size
    if len(frame) < table_end:
        raise FrameFormatError("truncated block table")
    blocks = []
    off = table_end
    for i in range(count):
        fields = entry.unpack_from(frame, table_start + i * entry.size)
        usize, cf = fields[0], fields[1]
        crc = fields[2] if version != VERSION_V1 else None
        raw = bool(cf & RAW_FLAG)
        csize = cf & ~RAW_FLAG
        if usize > MAX_BLOCK:
            raise FrameFormatError(f"block {i}: usize {usize} > {MAX_BLOCK}")
        if raw and csize != usize:
            raise FrameFormatError(f"block {i}: raw csize {csize} != usize {usize}")
        blocks.append({"usize": usize, "csize": csize, "raw": raw,
                       "offset": off, "crc": crc})
        off += csize
    if off != len(frame):
        raise FrameFormatError(
            f"frame length {len(frame)} != header-implied {off}"
        )
    if content_size is not None:
        total = sum(b["usize"] for b in blocks)
        if total != content_size:
            raise FrameFormatError(
                f"content size {content_size} != block-table total {total}"
            )
    return {"version": version, "block_count": count, "blocks": blocks,
            "content_size": content_size}


def check_block(i: int, usize: int, crc: int | None, data: bytes) -> None:
    """Validate one decoded block against its table entry (size + crc).

    The single source of truth for post-decode block validation — shared by
    `decode_frame_serial` and the decode engine's worker tasks so the oracle
    and the engine can never drift on which frames they reject.
    """
    if len(data) != usize:
        raise FrameFormatError(
            f"block {i}: decoded {len(data)} bytes, table says {usize}"
        )
    if crc is not None and block_crc(data) != crc:
        raise FrameFormatError(f"block {i}: checksum mismatch")


def decode_frame(frame: bytes) -> bytes:
    """Frame -> original bytes; raises FrameFormatError on any malformation.

    Delegates to the process-wide `LZ4DecodeEngine` (two-phase plan/execute
    decode, independent blocks fanned across a thread pool).  The serial
    block walk survives as `decode_frame_serial`, the oracle the engine is
    tested against.
    """
    from .decode_engine import default_decode_engine  # local: frame <-> engine

    return default_decode_engine().decode(frame)


def decode_frame_serial(frame: bytes, bytewise: bool = False) -> bytes:
    """Serial oracle: walk blocks in order with the scalar block decoder.

    ``bytewise=True`` uses the byte-at-a-time reference decoder for a fully
    independent second opinion (slowest, most obviously correct).
    """
    info = frame_info(frame)
    decode = decode_block_bytewise if bytewise else decode_block
    out = bytearray()
    for i, b in enumerate(info["blocks"]):
        payload = frame[b["offset"]: b["offset"] + b["csize"]]
        if b["raw"]:
            data = payload
        else:
            try:
                data = decode(payload, max_out=b["usize"])
            except FrameFormatError:
                raise
            except LZ4FormatError as e:
                raise FrameFormatError(f"block {i}: {e}") from e
        check_block(i, b["usize"], b["crc"], data)
        out += data
    return bytes(out)
