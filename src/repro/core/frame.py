"""Self-describing multi-block container (LZ4-frame-style) with a seek index.

The normative byte-level specification of this format — complete enough for
a third party to implement an independent reader — lives in
docs/frame-format.md; this docstring is the working summary.

The raw block format needs out-of-band lengths: a list of compressed blocks
is not decodable without knowing where each block ends and how large it was
uncompressed.  This container makes `LZ4Engine.compress` output a single
self-describing byte string:

    frame  := magic(4) | version(1) | block_count(u32 LE)
              [content_size(u64 LE)]                          (version 3)
              | table | payloads
    table  := block_count x entry
    entry  := usize(u32 LE) | csize_flag(u32 LE)              (version 1)
            | usize(u32 LE) | csize_flag(u32 LE) | crc32(u32) (versions 2, 3)

`csize_flag` holds the payload size in the low 31 bits; the high bit marks an
uncompressible block stored raw (payload == original bytes, csize == usize).
Payloads are concatenated in block order immediately after the table.
Version 2 adds a CRC32 of each block's *uncompressed* content, so any stored
corruption — including a flipped literal byte that still parses — is detected
at decode time instead of surfacing as silent wrong output.  Version 3
additionally records the TOTAL content size in the header; `frame_info`
cross-checks it against the block table's usize sum, so a corrupted table
(or header) is rejected before any payload is decoded and readers can size
output buffers from the header alone.

Version 4 (the sharded-fabric container, written by a sharded `LZ4Engine`)
adds a `shard_count` header field and a per-entry `shard` id recording which
mesh shard produced each block:

    frame  := magic(4) | version=4 | block_count(u32 LE)
              | content_size(u64 LE) | shard_count(u32 LE)
              | table | payloads
    entry  := usize(u32) | csize_flag(u32) | crc32(u32) | shard(u32)

Blocks stay in GLOBAL content order (shards compress contiguous slices of
the block stack, so concatenating per-shard outputs in shard order preserves
it); the shard column is provenance plus a validation surface.  A reader
MUST reject a shard id >= shard_count and a shard column that ever
decreases — per-shard runs are contiguous by construction, so an
out-of-order entry means the table was corrupted or the merge was wrong.
Seekability is unchanged: the cumulative usize sum still maps any
decompressed range to covering blocks regardless of shard boundaries.

Version 5 appends a whole-object integrity trailer to the version-4 layout:

    frame  := magic(4) | version=5 | block_count(u32 LE)
              | content_size(u64 LE) | shard_count(u32 LE)
              | table | payloads | content_crc(u32 LE)
    entry  := usize(u32) | csize_flag(u32) | crc32(u32) | shard(u32)

`content_crc` is the CRC32 of the CONCATENATED uncompressed content — a
second, independent integrity surface over the whole object on top of the
per-block CRCs (per-block checks cannot catch a table that swaps two
equal-sized blocks' entries, or a reader bug that joins blocks in the
wrong order).  Full-frame decoders (`decode_frame_serial`, the decode
engine's `decode`/`decode_to_device`) verify it after the join; PARTIAL
reads (`FrameReader.read_range`) deliberately skip it — they never
materialise the whole object, which is the point of the seek index.
Unsharded version-5 writers record `shard_count = 1` with every block on
shard 0.

Version 6 (opt-in via ``LZ4Engine(parity_group=N)``) adds an erasure-coding
surface on top of the version-5 layout so salvage (`repro.resilience`) can
*reconstruct* damage instead of merely mapping it:

    frame  := magic(4) | version=6 | block_count(u32 LE)
              | content_size(u64 LE) | shard_count(u32 LE)
              | parity_group(u32 LE)
              | table | payloads | ptable | parity_payloads
              | content_crc(u32 LE)
    entry  := usize(u32) | csize_flag(u32) | crc32(u32) | shard(u32)
    ptable := n_groups x pentry        n_groups = ceil(block_count / G)
    pentry := plen(u32) | pcrc(u32)

where ``G = parity_group >= 1``.  Data blocks are split into consecutive
groups of G; parity payload g is the byte-wise XOR of the group's STORED
payloads (compressed or raw, each zero-padded to ``plen``, the group's
maximum csize), and ``pcrc`` is the CRC32 of the parity payload itself.
Any SINGLE damaged payload in a group is reconstructed byte-identically by
XOR-ing the parity payload with the group's surviving payloads and
truncating to the damaged entry's table csize — then re-validated through
the normal decode + per-block CRC path, so a wrong reconstruction (two
overlapping faults, damaged parity) can never be returned silently.
Readers that never salvage can ignore parity entirely: the block table and
payload region are laid out exactly as in version 5, so partial reads
(`FrameReader.read_range`) skip the parity section for free, and full
decodes only add the (always-present in v6) whole-content trailer check.
Worked example + failure-mode table: docs/frame-format.md,
docs/resilience.md.

The block table is a public seek index (Rapidgzip-style, arXiv 2308.08955):
blocks are compressed independently, `frame_info` exposes each block's
`usize`/`csize`/payload `offset` without touching payload bytes, and the
cumulative sum of `usize` maps any decompressed byte range to the covering
blocks.  `FrameReader.read_range` (decode_engine.py) uses exactly this to
decode only the blocks a partial read needs; consumers may likewise seek by
indexing the table directly.

Kept deliberately minimal otherwise (no dictionaries, no entropy stage): the
point is self-description, seekability, and the raw-passthrough escape hatch
the paper's hardware also needs for incompressible inputs.

Decoding entry points:

  decode_frame         — delegates to the parallel two-phase
                         `LZ4DecodeEngine` (decode_engine.py).
  decode_frame_serial  — the original serial block walk, kept as the oracle
                         (`bytewise=True` drops to the byte-at-a-time block
                         decoder for a fully independent reference).
"""
from __future__ import annotations

import binascii
import struct

from .decoder import LZ4FormatError, decode_block, decode_block_bytewise
from .lz4_types import MAX_BLOCK

MAGIC = b"LZ4R"
VERSION_V1 = 1
VERSION_V2 = 2
VERSION_V3 = 3
VERSION_V4 = 4
VERSION_V5 = 5
VERSION_V6 = 6
VERSION = VERSION_V3  # unsharded writer version (checksums + content size)
RAW_FLAG = 0x80000000
_HEADER = struct.Struct("<4sBI")
_CONTENT_SIZE = struct.Struct("<Q")  # v3+: total uncompressed size
_SHARD_COUNT = struct.Struct("<I")   # v4+: shard count
_PARITY_GROUP = struct.Struct("<I")  # v6: data blocks per parity group
_ENTRY_V1 = struct.Struct("<II")
_ENTRY_V2 = struct.Struct("<III")   # also the v3 entry
_ENTRY_V4 = struct.Struct("<IIII")  # v2 entry + producing shard id (v4/v5/v6)
_PARITY_ENTRY = struct.Struct("<II")  # v6: padded length + parity-payload CRC
_CONTENT_CRC = struct.Struct("<I")  # v5/v6 trailer: whole-content CRC32
_ALL_VERSIONS = (VERSION_V1, VERSION_V2, VERSION_V3, VERSION_V4, VERSION_V5,
                 VERSION_V6)


class FrameFormatError(LZ4FormatError):
    """Malformed frame: bad magic/version, truncation, lying size fields,
    or (version >= 2) a block checksum mismatch."""


def block_crc(data: bytes) -> int:
    """The frame's per-block checksum: CRC32 of the uncompressed content."""
    return binascii.crc32(data) & 0xFFFFFFFF


def xor_bytes(parts: list[bytes], length: int | None = None) -> bytes:
    """Byte-wise XOR of ``parts``, each zero-padded to ``length`` (defaults
    to the longest part).  The v6 parity primitive — and, because XOR is its
    own inverse, also the reconstruction primitive: XOR of a group's parity
    payload with its surviving payloads yields the missing payload
    (zero-padded; truncate to its table csize)."""
    if length is None:
        length = max((len(p) for p in parts), default=0)
    acc = 0
    for p in parts:
        if len(p) > length:
            raise ValueError(f"part of {len(p)} bytes > parity length {length}")
        acc ^= int.from_bytes(p, "little")
    return acc.to_bytes(length, "little")


def parity_group_blocks(payloads: list[bytes],
                        group: int) -> list[tuple[int, int, bytes]]:
    """Compute the v6 parity section for ``payloads`` (STORED block bytes,
    in table order): one ``(plen, pcrc, parity_payload)`` per consecutive
    group of ``group`` blocks (the last group may be short)."""
    if group < 1:
        raise ValueError("parity_group must be >= 1")
    out = []
    for g0 in range(0, len(payloads), group):
        grp = [bytes(p) for p in payloads[g0: g0 + group]]
        parity = xor_bytes(grp)
        out.append((len(parity), block_crc(parity), parity))
    return out


def encode_frame(payloads: list[bytes], usizes: list[int],
                 raw_flags: list[bool],
                 checksums: list[int] | None = None,
                 content_size: bool = True,
                 shards: list[int] | None = None,
                 shard_count: int | None = None,
                 content_crc: int | None = None,
                 parity_group: int | None = None) -> bytes:
    """Assemble a frame from per-block payloads.

    payloads  : compressed block bytes (or raw input bytes where flagged)
    usizes    : uncompressed size of each block
    raw_flags : True where the payload is stored raw (uncompressible block)
    checksums : optional per-block `block_crc` of the UNCOMPRESSED content;
                when given the frame is written as version 3 (verified on
                decode), otherwise as version 1 (no integrity check).
    content_size : write the total uncompressed size into the header
                (version 3; requires checksums).  ``False`` produces a
                version-2 frame, byte-identical to the pre-v3 writer.
    shards    : per-block producing-shard ids (the sharded fabric's merge
                stage).  When given the frame is written as version 4:
                ids must be non-decreasing (shards own contiguous block
                runs) and < ``shard_count``.  Requires checksums +
                content_size.
    shard_count : total shard count recorded in the v4 header; defaults to
                ``max(shards) + 1`` (``1`` for an empty frame).  May exceed
                the largest id present — trailing shards can own zero
                blocks when the stack does not divide.
    content_crc : CRC32 of the CONCATENATED uncompressed content.  When
                given the frame is written as version 5 — the version-4
                layout plus a 4-byte trailer — and full-frame decoders
                verify the joined output against it.  Requires checksums +
                content_size; an unsharded version-5 frame records
                ``shard_count = 1`` with every block on shard 0.
    parity_group : data blocks per XOR parity group.  When given the frame
                is written as version 6 — the version-5 layout plus a
                ``parity_group`` header field and one parity block per
                group of that many data blocks (`parity_group_blocks`) —
                so salvage can reconstruct any single damaged block per
                group byte-identically.  Requires ``content_crc``.
    """
    if not (len(payloads) == len(usizes) == len(raw_flags)):
        raise ValueError("payloads/usizes/raw_flags length mismatch")
    if checksums is not None and len(checksums) != len(payloads):
        raise ValueError("checksums length mismatch")
    if parity_group is not None:
        if parity_group < 1:
            raise ValueError("parity_group must be >= 1")
        if content_crc is None:
            raise ValueError("version-6 frames require content_crc")
    if content_crc is not None:
        if checksums is None or not content_size:
            raise ValueError("version-5 frames require checksums + content_size")
        if shards is None:
            shards = [0] * len(payloads)
    if shards is not None:
        if checksums is None or not content_size:
            raise ValueError("version-4 frames require checksums + content_size")
        if len(shards) != len(payloads):
            raise ValueError("shards length mismatch")
        if shard_count is None:
            shard_count = (max(shards) + 1) if shards else 1
        if shard_count < 1:
            raise ValueError("shard_count must be >= 1")
        if any(s1 < s0 for s0, s1 in zip(shards, shards[1:])):
            raise ValueError("shard ids must be non-decreasing")
        if shards and (shards[0] < 0 or shards[-1] >= shard_count):
            raise ValueError("shard id out of range")
        if parity_group is not None:
            version = VERSION_V6
        elif content_crc is not None:
            version = VERSION_V5
        else:
            version = VERSION_V4
    elif checksums is None:
        version = VERSION_V1
    else:
        version = VERSION_V3 if content_size else VERSION_V2
    wide = version in (VERSION_V4, VERSION_V5, VERSION_V6)
    parts = [_HEADER.pack(MAGIC, version, len(payloads))]
    if version >= VERSION_V3:
        parts.append(_CONTENT_SIZE.pack(sum(usizes)))
    if wide:
        parts.append(_SHARD_COUNT.pack(shard_count))
    if version == VERSION_V6:
        parts.append(_PARITY_GROUP.pack(parity_group))
    for i, (payload, usize, raw) in enumerate(zip(payloads, usizes, raw_flags)):
        if not 0 <= usize <= MAX_BLOCK:
            raise ValueError(f"block uncompressed size {usize} out of range")
        if raw and len(payload) != usize:
            raise ValueError("raw block payload must equal its usize")
        if len(payload) >= RAW_FLAG:
            raise ValueError("block payload too large")
        cf = len(payload) | (RAW_FLAG if raw else 0)
        if wide:
            parts.append(_ENTRY_V4.pack(usize, cf, checksums[i] & 0xFFFFFFFF,
                                        shards[i]))
        elif checksums is None:
            parts.append(_ENTRY_V1.pack(usize, cf))
        else:
            parts.append(_ENTRY_V2.pack(usize, cf, checksums[i] & 0xFFFFFFFF))
    parts.extend(bytes(p) for p in payloads)
    if version == VERSION_V6:
        groups = parity_group_blocks([bytes(p) for p in payloads],
                                     parity_group)
        for plen, pcrc, _ in groups:
            parts.append(_PARITY_ENTRY.pack(plen, pcrc))
        for _, _, parity in groups:
            parts.append(parity)
    if version in (VERSION_V5, VERSION_V6):
        parts.append(_CONTENT_CRC.pack(content_crc & 0xFFFFFFFF))
    return b"".join(parts)


def frame_info(frame: bytes, max_version: int | None = None) -> dict:
    """Parse and validate the header/table; returns block metadata.

    Raises FrameFormatError without touching any payload bytes.  Each block
    dict carries the seek-index fields: `usize`, `csize`, `raw`, payload
    `offset` into the frame, `crc` (None for version-1 frames), and `shard`
    (the producing shard for version-4 frames, None before).  The result's
    `content_size` is the version-3/4 header total (None for older
    versions), already validated against the table's usize sum — so a
    corrupted table or header field is caught BEFORE any payload decode;
    `shard_count` is the version-4/5 shard total (None before), with every
    table shard id validated in-range and non-decreasing; `content_crc` is
    the version-5 whole-content CRC32 trailer (None before v5) — exposed
    for full-frame decoders to verify after the join, never checked here
    (the header/table pass touches no payload bytes).

    ``max_version`` pins the reader's format horizon: a deployment still
    running the version-3 reader rejects version-4 frames outright instead
    of misparsing the wider table (tests assert this guard), exactly as the
    pre-v4 code did via its version allowlist.
    """
    if len(frame) < _HEADER.size:
        raise FrameFormatError("truncated frame header", cause="truncated")
    magic, version, count = _HEADER.unpack_from(frame, 0)
    if magic != MAGIC:
        raise FrameFormatError(f"bad magic {magic!r}", cause="structure")
    if version not in _ALL_VERSIONS:
        raise FrameFormatError(f"unsupported frame version {version}",
                               cause="structure")
    if max_version is not None and version > max_version:
        raise FrameFormatError(
            f"frame version {version} > reader max_version {max_version}",
            cause="structure",
        )
    table_start = _HEADER.size
    content_size = None
    shard_count = None
    parity_group = None
    wide = version in (VERSION_V4, VERSION_V5, VERSION_V6)
    if version >= VERSION_V3:
        if len(frame) < table_start + _CONTENT_SIZE.size:
            raise FrameFormatError("truncated content-size header",
                                   cause="truncated")
        (content_size,) = _CONTENT_SIZE.unpack_from(frame, table_start)
        table_start += _CONTENT_SIZE.size
    if wide:
        if len(frame) < table_start + _SHARD_COUNT.size:
            raise FrameFormatError("truncated shard-count header",
                                   cause="truncated")
        (shard_count,) = _SHARD_COUNT.unpack_from(frame, table_start)
        table_start += _SHARD_COUNT.size
        if shard_count < 1:
            raise FrameFormatError("shard_count must be >= 1",
                                   cause="structure")
    if version == VERSION_V6:
        if len(frame) < table_start + _PARITY_GROUP.size:
            raise FrameFormatError("truncated parity-group header",
                                   cause="truncated")
        (parity_group,) = _PARITY_GROUP.unpack_from(frame, table_start)
        table_start += _PARITY_GROUP.size
        if parity_group < 1:
            raise FrameFormatError("parity_group must be >= 1",
                                   cause="structure")
    entry = _ENTRY_V4 if wide else (
        _ENTRY_V1 if version == VERSION_V1 else _ENTRY_V2)
    table_end = table_start + count * entry.size
    if len(frame) < table_end:
        raise FrameFormatError("truncated block table", cause="truncated")
    blocks = []
    off = table_end
    prev_shard = 0
    for i in range(count):
        fields = entry.unpack_from(frame, table_start + i * entry.size)
        usize, cf = fields[0], fields[1]
        crc = fields[2] if version != VERSION_V1 else None
        shard = fields[3] if wide else None
        raw = bool(cf & RAW_FLAG)
        csize = cf & ~RAW_FLAG
        if usize > MAX_BLOCK:
            raise FrameFormatError(f"block {i}: usize {usize} > {MAX_BLOCK}",
                                   block_index=i, cause="structure")
        if raw and csize != usize:
            raise FrameFormatError(
                f"block {i}: raw csize {csize} != usize {usize}",
                block_index=i, cause="structure")
        if shard is not None:
            if shard >= shard_count:
                raise FrameFormatError(
                    f"block {i}: shard {shard} >= shard_count {shard_count}",
                    block_index=i, cause="structure",
                )
            if shard < prev_shard:
                raise FrameFormatError(
                    f"block {i}: shard {shard} after shard {prev_shard} — "
                    "shard runs must be contiguous and in order",
                    block_index=i, cause="structure",
                )
            prev_shard = shard
        blocks.append({"usize": usize, "csize": csize, "raw": raw,
                       "offset": off, "crc": crc, "shard": shard})
        off += csize
    parity = None
    if version == VERSION_V6:
        n_groups = (count + parity_group - 1) // parity_group
        ptable_end = off + n_groups * _PARITY_ENTRY.size
        if len(frame) < ptable_end:
            raise FrameFormatError("truncated parity table",
                                   cause="truncated")
        parity = []
        poff = ptable_end
        for g in range(n_groups):
            plen, pcrc = _PARITY_ENTRY.unpack_from(
                frame, off + g * _PARITY_ENTRY.size)
            grp = blocks[g * parity_group: (g + 1) * parity_group]
            want = max(b["csize"] for b in grp)
            if plen != want:
                raise FrameFormatError(
                    f"parity group {g}: plen {plen} != group max csize {want}",
                    cause="structure",
                )
            parity.append({"plen": plen, "crc": pcrc, "offset": poff})
            poff += plen
        off = poff
    content_crc = None
    if version in (VERSION_V5, VERSION_V6):
        if off + _CONTENT_CRC.size != len(frame):
            raise FrameFormatError(
                f"frame length {len(frame)} != header-implied "
                f"{off + _CONTENT_CRC.size}",
                cause="truncated" if len(frame) < off + _CONTENT_CRC.size
                else "structure",
            )
        (content_crc,) = _CONTENT_CRC.unpack_from(frame, off)
    elif off != len(frame):
        raise FrameFormatError(
            f"frame length {len(frame)} != header-implied {off}",
            cause="truncated" if len(frame) < off else "structure",
        )
    if content_size is not None:
        total = sum(b["usize"] for b in blocks)
        if total != content_size:
            raise FrameFormatError(
                f"content size {content_size} != block-table total {total}",
                cause="structure",
            )
    return {"version": version, "block_count": count, "blocks": blocks,
            "content_size": content_size, "shard_count": shard_count,
            "content_crc": content_crc, "parity_group": parity_group,
            "parity": parity}


def scan_frame(frame: bytes) -> dict:
    """Tolerant header/table parse for salvage (`repro.resilience.salvage`).

    Where `frame_info` is all-or-nothing — one lying table field rejects the
    whole frame — `scan_frame` recovers as much structural metadata as the
    bytes support.  An intact frame takes the strict path and returns the
    `frame_info` dict plus ``complete=True`` / ``notes=[]``; a damaged one
    falls back to a tolerant walk that keeps every table row it can read:

      blocks : one dict per readable table row (same keys as `frame_info`
               plus ``ok`` — False when the entry is structurally invalid
               or its payload region runs past the end of the frame — and
               ``note`` describing why).  Offsets are computed cumulatively
               exactly as the writer laid payloads out, so rows AFTER a
               garbage csize may also go ``ok=False``; that is honest —
               their true position is unrecoverable without parity.
      parity : v6 parity-group dicts (``plen``/``crc``/``offset``/``ok``),
               or None when the parity section is unreadable.
      complete : False on the tolerant path.
      notes  : human-readable anomaly list (every reason the strict parse
               would have rejected the frame).

    Still raises `FrameFormatError` when there is nothing to salvage *with*:
    a frame too short for the fixed header, wrong magic, or an unknown
    version — no block table can be located then.  Never touches payload
    bytes; payload damage (the common case) is only discoverable by
    decoding, which is salvage's job.
    """
    try:
        info = frame_info(frame)
    except FrameFormatError:
        pass
    else:
        info["complete"] = True
        info["notes"] = []
        for b in info["blocks"]:
            b["ok"] = True
            b["note"] = None
        if info["parity"] is not None:
            for p in info["parity"]:
                p["ok"] = True
        return info
    if len(frame) < _HEADER.size:
        raise FrameFormatError("truncated frame header", cause="truncated")
    magic, version, count = _HEADER.unpack_from(frame, 0)
    if magic != MAGIC:
        raise FrameFormatError(f"bad magic {magic!r}", cause="structure")
    if version not in _ALL_VERSIONS:
        raise FrameFormatError(f"unsupported frame version {version}",
                               cause="structure")
    notes: list[str] = []
    table_start = _HEADER.size
    content_size = None
    shard_count = None
    parity_group = None
    wide = version in (VERSION_V4, VERSION_V5, VERSION_V6)
    if version >= VERSION_V3:
        if len(frame) >= table_start + _CONTENT_SIZE.size:
            (content_size,) = _CONTENT_SIZE.unpack_from(frame, table_start)
        else:
            notes.append("truncated content-size header")
        table_start += _CONTENT_SIZE.size
    if wide:
        if len(frame) >= table_start + _SHARD_COUNT.size:
            (shard_count,) = _SHARD_COUNT.unpack_from(frame, table_start)
            if shard_count < 1:
                notes.append("shard_count must be >= 1")
                shard_count = None
        else:
            notes.append("truncated shard-count header")
        table_start += _SHARD_COUNT.size
    if version == VERSION_V6:
        if len(frame) >= table_start + _PARITY_GROUP.size:
            (parity_group,) = _PARITY_GROUP.unpack_from(frame, table_start)
            if parity_group < 1:
                notes.append("parity_group must be >= 1")
                parity_group = None
        else:
            notes.append("truncated parity-group header")
        table_start += _PARITY_GROUP.size
    entry = _ENTRY_V4 if wide else (
        _ENTRY_V1 if version == VERSION_V1 else _ENTRY_V2)
    table_end = table_start + count * entry.size
    readable = min(count, max(0, (len(frame) - table_start)) // entry.size)
    if readable < count:
        notes.append(f"truncated block table: {readable}/{count} entries")
    blocks = []
    off = table_end
    for i in range(readable):
        fields = entry.unpack_from(frame, table_start + i * entry.size)
        usize, cf = fields[0], fields[1]
        crc = fields[2] if version != VERSION_V1 else None
        shard = fields[3] if wide else None
        raw = bool(cf & RAW_FLAG)
        csize = cf & ~RAW_FLAG
        note = None
        if usize > MAX_BLOCK:
            note = f"usize {usize} > {MAX_BLOCK}"
        elif raw and csize != usize:
            note = f"raw csize {csize} != usize {usize}"
        elif shard is not None and shard_count is not None \
                and shard >= shard_count:
            note = f"shard {shard} >= shard_count {shard_count}"
        elif off + csize > len(frame):
            note = "payload runs past end of frame"
        if note is not None:
            notes.append(f"block {i}: {note}")
        blocks.append({"usize": usize, "csize": csize, "raw": raw,
                       "offset": off, "crc": crc, "shard": shard,
                       "ok": note is None, "note": note})
        off += csize
    parity = None
    if version == VERSION_V6 and parity_group is not None \
            and readable == count:
        n_groups = (count + parity_group - 1) // parity_group
        ptable_end = off + n_groups * _PARITY_ENTRY.size
        if ptable_end <= len(frame):
            parity = []
            poff = ptable_end
            for g in range(n_groups):
                plen, pcrc = _PARITY_ENTRY.unpack_from(
                    frame, off + g * _PARITY_ENTRY.size)
                grp = blocks[g * parity_group: (g + 1) * parity_group]
                want = max(b["csize"] for b in grp)
                pnote = None
                if plen != want:
                    pnote = f"plen {plen} != group max csize {want}"
                elif poff + plen > len(frame):
                    pnote = "parity payload runs past end of frame"
                if pnote is not None:
                    notes.append(f"parity group {g}: {pnote}")
                parity.append({"plen": plen, "crc": pcrc, "offset": poff,
                               "ok": pnote is None})
                poff += plen
        else:
            notes.append("truncated parity table")
    elif version == VERSION_V6:
        notes.append("parity section unreadable (damaged header or table)")
    content_crc = None
    if version in (VERSION_V5, VERSION_V6):
        tail = (off if parity is None
                else parity[-1]["offset"] + parity[-1]["plen"] if parity
                else off)
        if all(b["ok"] for b in blocks) and readable == count \
                and tail + _CONTENT_CRC.size <= len(frame):
            (content_crc,) = _CONTENT_CRC.unpack_from(frame, tail)
        else:
            notes.append("content-crc trailer unreadable")
    if content_size is not None and readable == count:
        total = sum(b["usize"] for b in blocks)
        if total != content_size:
            notes.append(
                f"content size {content_size} != block-table total {total}")
    return {"version": version, "block_count": count, "blocks": blocks,
            "content_size": content_size, "shard_count": shard_count,
            "content_crc": content_crc, "parity_group": parity_group,
            "parity": parity, "complete": False, "notes": notes}


def check_block(i: int, usize: int, crc: int | None, data: bytes) -> None:
    """Validate one decoded block against its table entry (size + crc).

    The single source of truth for post-decode block validation — shared by
    `decode_frame_serial` and the decode engine's worker tasks so the oracle
    and the engine can never drift on which frames they reject.
    """
    if len(data) != usize:
        raise FrameFormatError(
            f"block {i}: decoded {len(data)} bytes, table says {usize}",
            block_index=i, cause="size",
        )
    if crc is not None and block_crc(data) != crc:
        raise FrameFormatError(f"block {i}: checksum mismatch",
                               block_index=i, cause="crc")


def check_content_crc(expected: int | None, crc: int) -> None:
    """Validate the joined output's CRC32 against the v5 trailer.

    `expected` is `frame_info(...)["content_crc"]` (None before version 5 —
    a no-op then); `crc` is `block_crc` over the full decoded object, or an
    equivalent in-graph CRC32.  Shared by every full-frame decode path so
    they reject identically; partial reads never call it.
    """
    if expected is not None and crc != expected:
        raise FrameFormatError("content checksum mismatch",
                               cause="content_crc")


def decode_frame(frame: bytes) -> bytes:
    """Frame -> original bytes; raises FrameFormatError on any malformation.

    Delegates to the process-wide `LZ4DecodeEngine` (two-phase plan/execute
    decode, independent blocks fanned across a thread pool).  The serial
    block walk survives as `decode_frame_serial`, the oracle the engine is
    tested against.
    """
    from .decode_engine import default_decode_engine  # local: frame <-> engine

    return default_decode_engine().decode(frame)


def decode_frame_serial(frame: bytes, bytewise: bool = False) -> bytes:
    """Serial oracle: walk blocks in order with the scalar block decoder.

    ``bytewise=True`` uses the byte-at-a-time reference decoder for a fully
    independent second opinion (slowest, most obviously correct).
    """
    info = frame_info(frame)
    decode = decode_block_bytewise if bytewise else decode_block
    out = bytearray()
    for i, b in enumerate(info["blocks"]):
        payload = frame[b["offset"]: b["offset"] + b["csize"]]
        if b["raw"]:
            data = payload
        else:
            try:
                data = decode(payload, max_out=b["usize"])
            except FrameFormatError:
                raise
            except LZ4FormatError as e:
                raise FrameFormatError(f"block {i}: {e}") from e
        check_block(i, b["usize"], b["crc"], data)
        out += data
    check_content_crc(info["content_crc"], block_crc(bytes(out)))
    return bytes(out)
