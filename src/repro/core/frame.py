"""Self-describing multi-block container (LZ4-frame-style).

The raw block format needs out-of-band lengths: a list of compressed blocks
is not decodable without knowing where each block ends and how large it was
uncompressed.  This container makes `LZ4Engine.compress` output a single
self-describing byte string:

    frame  := magic(4) | version(1) | block_count(u32 LE) | table | payloads
    table  := block_count x { usize(u32 LE) | csize_flag(u32 LE) }

`csize_flag` holds the payload size in the low 31 bits; the high bit marks an
uncompressible block stored raw (payload == original bytes, csize == usize).
Payloads are concatenated in block order immediately after the table.

Kept deliberately minimal (no checksums, no dictionaries): the point is
self-description and the raw-passthrough escape hatch the paper's hardware
also needs for incompressible inputs.
"""
from __future__ import annotations

import struct

from .decoder import LZ4FormatError, decode_block
from .lz4_types import MAX_BLOCK

MAGIC = b"LZ4R"
VERSION = 1
RAW_FLAG = 0x80000000
_HEADER = struct.Struct("<4sBI")
_ENTRY = struct.Struct("<II")


class FrameFormatError(LZ4FormatError):
    """Malformed frame: bad magic/version, truncation, or lying size fields."""


def encode_frame(payloads: list[bytes], usizes: list[int],
                 raw_flags: list[bool]) -> bytes:
    """Assemble a frame from per-block payloads.

    payloads  : compressed block bytes (or raw input bytes where flagged)
    usizes    : uncompressed size of each block
    raw_flags : True where the payload is stored raw (uncompressible block)
    """
    if not (len(payloads) == len(usizes) == len(raw_flags)):
        raise ValueError("payloads/usizes/raw_flags length mismatch")
    parts = [_HEADER.pack(MAGIC, VERSION, len(payloads))]
    for payload, usize, raw in zip(payloads, usizes, raw_flags):
        if not 0 <= usize <= MAX_BLOCK:
            raise ValueError(f"block uncompressed size {usize} out of range")
        if raw and len(payload) != usize:
            raise ValueError("raw block payload must equal its usize")
        if len(payload) >= RAW_FLAG:
            raise ValueError("block payload too large")
        parts.append(_ENTRY.pack(usize, len(payload) | (RAW_FLAG if raw else 0)))
    parts.extend(bytes(p) for p in payloads)
    return b"".join(parts)


def frame_info(frame: bytes) -> dict:
    """Parse and validate the header/table; returns block metadata.

    Raises FrameFormatError without touching any payload bytes.
    """
    if len(frame) < _HEADER.size:
        raise FrameFormatError("truncated frame header")
    magic, version, count = _HEADER.unpack_from(frame, 0)
    if magic != MAGIC:
        raise FrameFormatError(f"bad magic {magic!r}")
    if version != VERSION:
        raise FrameFormatError(f"unsupported frame version {version}")
    table_end = _HEADER.size + count * _ENTRY.size
    if len(frame) < table_end:
        raise FrameFormatError("truncated block table")
    blocks = []
    off = table_end
    for i in range(count):
        usize, cf = _ENTRY.unpack_from(frame, _HEADER.size + i * _ENTRY.size)
        raw = bool(cf & RAW_FLAG)
        csize = cf & ~RAW_FLAG
        if usize > MAX_BLOCK:
            raise FrameFormatError(f"block {i}: usize {usize} > {MAX_BLOCK}")
        if raw and csize != usize:
            raise FrameFormatError(f"block {i}: raw csize {csize} != usize {usize}")
        blocks.append({"usize": usize, "csize": csize, "raw": raw, "offset": off})
        off += csize
    if off != len(frame):
        raise FrameFormatError(
            f"frame length {len(frame)} != header-implied {off}"
        )
    return {"version": version, "block_count": count, "blocks": blocks}


def decode_frame(frame: bytes) -> bytes:
    """Frame -> original bytes; raises FrameFormatError on any malformation."""
    info = frame_info(frame)
    out = bytearray()
    for i, b in enumerate(info["blocks"]):
        payload = frame[b["offset"]: b["offset"] + b["csize"]]
        if b["raw"]:
            out += payload
            continue
        try:
            data = decode_block(payload, max_out=b["usize"])
        except FrameFormatError:
            raise
        except LZ4FormatError as e:
            raise FrameFormatError(f"block {i}: {e}") from e
        if len(data) != b["usize"]:
            raise FrameFormatError(
                f"block {i}: decoded {len(data)} bytes, table says {b['usize']}"
            )
        out += data
    return bytes(out)
