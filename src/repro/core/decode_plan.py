"""Two-phase block decode: parse the token stream once into a flat copy plan.

This is the software analogue of the paper's feedback-free pipeline run in
reverse (and of Sitaridi et al., arXiv 1606.00519, on GPUs): instead of
interleaving *parsing* (serial by construction — every sequence's position
depends on the previous one) with *copying* (bulk data movement), we separate
them:

  plan_block     — one pass over the token stream; no byte is copied.  The
                   result is a ``BlockPlan``: flat NumPy arrays of literal
                   spans (src in the block, dst in the output) and match
                   copies (dst, src = dst - offset, length).  All format
                   validation happens here, with the output cap enforced
                   BEFORE each span is admitted to the plan, so a malicious
                   length field can never force an allocation past `max_out`.
  execute_plan   — bulk execution: every literal span lands with ONE fancy-
                   index gather; match copies run in dependency *waves* —
                   each wave executes every match whose source bytes are
                   already materialized as one vectorized gather/scatter
                   (matches only ever read output produced strictly before
                   their own write position, so readiness is an interval
                   query against the still-pending write intervals, fully
                   vectorizable because write intervals are disjoint and
                   sorted).  Pathological chains (e.g. RLE-style blocks where
                   every match reads the previous match's output) would
                   degrade to one match per wave, so after ``wave_limit``
                   waves — or when a wave goes thin — execution falls back to
                   an in-order chunked copy loop, which is always correct.

`decode_block_planned` composes the two and is bit-identical to the serial
`decode_block` / `decode_block_bytewise` oracles (asserted in tests on
random, adversarial, and overlap-heavy corpora).

Device-side execution (the read-path mirror of the compress engine's
device-resident emit) needs one more shape: `BlockPlan` is ragged — every
block has a different number of literal runs and matches — but a jit graph
wants uniform arrays.  `DevicePlan` is the fixed-shape, padding-aware form:
flat int32 arrays sized by `DevicePlanCaps`, so a micro-batch of blocks
stacks into `(M, cap)` arrays exactly like the compress side's block stack.
`to_device_plan` converts (rejecting plans that exceed the caps with
`DevicePlanOverflow`, which callers turn into a host fallback), and
`execute_device_plan` is the NumPy oracle of the device algorithm:

  the dependency-wave formulation above is data-dependent (an RLE chain
  degrades to one match per wave — fine on the host, where a sequential
  fallback exists, fatal in a fixed-shape graph).  Instead, every output
  byte's *immediate* source is a pure function of the plan (literal bytes
  point at the input block, match bytes at output position ``k - offset``),
  and the transitive source is resolved by POINTER DOUBLING: after r
  rounds of ``ptr = ptr[ptr]`` every chain of depth <= 2^r lands on a
  literal byte, so ceil(log2(MAX_BLOCK)) = 16 rounds suffice for ANY valid
  block — pathological chains included, no fallback path.  `DevicePlan`'s
  per-sequence ``wave`` index records the round at which each match's bytes
  resolve; its max (``n_waves``) lets the decode engine compile graphs with
  fewer rounds for shallow micro-batches.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from .decoder import LZ4FormatError

__all__ = ["BlockPlan", "DevicePlan", "DevicePlanCaps", "DevicePlanOverflow",
           "MAX_RESOLVE_ROUNDS", "plan_block", "plan_block_fast",
           "execute_plan", "execute_device_plan", "to_device_plan",
           "decode_block_planned"]


@dataclasses.dataclass
class BlockPlan:
    """Flat copy plan for one block (all arrays int64, spans in bytes).

    Literal run r copies ``block[lit_src[r] : lit_src[r]+lit_len[r]]`` to
    output position ``lit_dst[r]``; match m copies ``match_len[m]`` bytes
    from output position ``match_src[m]`` to ``match_dst[m]`` (LZ4
    semantics: the ranges may overlap, in which case the copy replicates
    the ``match_dst - match_src``-wide pattern).  Literal and match dst
    spans together tile ``[0, usize)`` exactly.
    """

    usize: int
    lit_src: np.ndarray
    lit_dst: np.ndarray
    lit_len: np.ndarray
    match_dst: np.ndarray
    match_src: np.ndarray
    match_len: np.ndarray

    @property
    def n_sequences(self) -> int:
        return len(self.lit_len) + len(self.match_len)


def plan_block(block: bytes, max_out: int | None = None) -> BlockPlan:
    """Parse an LZ4 block into a BlockPlan without copying any payload bytes.

    Raises LZ4FormatError on every malformation the serial decoders reject,
    with identical semantics: the `max_out` cap is checked before a literal
    run or match copy is admitted, never after.
    """
    lit_src: list[int] = []
    lit_dst: list[int] = []
    lit_lens: list[int] = []
    m_dst: list[int] = []
    m_src: list[int] = []
    m_len: list[int] = []
    i = 0
    out_len = 0
    n = len(block)
    blk = block
    while True:
        if i >= n:
            raise LZ4FormatError("truncated block: missing token")
        token = blk[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated literal length")
                b = blk[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise LZ4FormatError("truncated literals")
        if max_out is not None and out_len + lit_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        if lit_len:
            lit_src.append(i)
            lit_dst.append(out_len)
            lit_lens.append(lit_len)
            out_len += lit_len
            i += lit_len
        if i == n:
            break  # final literals-only sequence
        if i + 2 > n:
            raise LZ4FormatError("truncated offset")
        offset = blk[i] | (blk[i + 1] << 8)
        i += 2
        if offset == 0:
            raise LZ4FormatError("zero offset")
        if offset > out_len:
            raise LZ4FormatError("offset beyond output")
        match_len = (token & 0xF) + 4
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise LZ4FormatError("truncated match length")
                b = blk[i]
                i += 1
                match_len += b
                if b != 255:
                    break
        if max_out is not None and out_len + match_len > max_out:
            raise LZ4FormatError("output exceeds limit")
        m_dst.append(out_len)
        m_src.append(out_len - offset)
        m_len.append(match_len)
        out_len += match_len
    a = lambda xs: np.asarray(xs, np.int64)
    return BlockPlan(
        usize=out_len,
        lit_src=a(lit_src), lit_dst=a(lit_dst), lit_len=a(lit_lens),
        match_dst=a(m_dst), match_src=a(m_src), match_len=a(m_len),
    )


# Below this size the Python parse beats the full-width NumPy prepass.
_FAST_MIN = 2048

# Sequence-order error priorities for the vectorized validator (must mirror
# the check order of plan_block / decode_block exactly).
_ERR_MESSAGES = {
    1: "truncated literal length",
    2: "truncated literals",
    3: "output exceeds limit",
    4: "truncated offset",
    5: "zero offset",
    6: "offset beyond output",
    7: "truncated match length",
    8: "output exceeds limit",
}


class _PlanWorkspace:
    """Per-thread reusable buffers for the vectorized planner.

    Fresh NumPy allocations cost first-touch page faults per op — orders of
    magnitude more than the arithmetic at 64 KB scale — so every full-width
    intermediate writes into preallocated arrays via ``out=``.  One
    workspace per worker thread (threading.local), sized for MAX_BLOCK and
    reused for every block the thread decodes.
    """

    CAP = 65536  # MAX_BLOCK; avoid importing lz4_types for one constant

    def __init__(self):
        c = self.CAP
        self.idx = np.arange(c, dtype=np.int32)
        self.idxp1 = np.arange(1, c + 1, dtype=np.int32)
        self.ui = np.empty(c, np.int32)
        self.ffrun = np.zeros(c + 1, np.int32)
        self.i = [np.empty(c, np.int32) for _ in range(8)]
        self.b = [np.empty(c, bool) for _ in range(4)]
        # Execute-phase span-gather scratch (indices + staging bytes).
        self.span_a = np.empty(c, np.int32)
        self.span_b = np.empty(c, np.int32)
        self.u8tmp = np.empty(c, np.uint8)
        # Touch every page once so reuse never faults.
        for a in (self.ui, self.ffrun, *self.i, *self.b,
                  self.span_a, self.span_b, self.u8tmp):
            a.fill(0)


_tls = threading.local()


def _workspace() -> _PlanWorkspace:
    ws = getattr(_tls, "plan_ws", None)
    if ws is None:
        ws = _tls.plan_ws = _PlanWorkspace()
    return ws


def plan_block_fast(block: bytes, max_out: int | None = None) -> BlockPlan:
    """Vectorized `plan_block`: identical plans, identical rejections.

    The serial parse is feedback-limited only through each sequence's
    *position*; every field is a pure function of its byte offset.  So:
    compute token nibbles, 0xFF-run lengths, extended literal/match lengths,
    offsets, and next-sequence positions for EVERY byte position with NumPy
    (the feedback-free part, all ``out=`` into a per-thread workspace), then
    follow the next[] chain from position 0 (one memoryview hop per sequence
    — the only serial residue), and validate all visited sequences with one
    vectorized pass that reproduces the serial decoder's per-sequence check
    order.
    """
    n = len(block)
    if n == 0:
        raise LZ4FormatError("truncated block: missing token")
    if n < _FAST_MIN or n > _PlanWorkspace.CAP:
        return plan_block(block, max_out=max_out)
    ws = _workspace()
    u8 = np.frombuffer(block, np.uint8)
    idx = ws.idx[:n]
    idxp1 = ws.idxp1[:n]
    ui = ws.ui[:n]
    np.copyto(ui, u8)
    i1, i2, i3, i4, i5, i6, i7, i8 = (a[:n] for a in ws.i)
    b1, b2, b3, b4 = (a[:n] for a in ws.b)

    # ffrun[i] = length of the 0xFF run starting at i (ffrun[n] == 0).
    np.equal(u8, 255, out=b1)
    rev = b1[::-1]
    np.copyto(i1, idx)
    np.copyto(i1, -1, where=rev)          # i1 = idx where NOT a 255-run, else -1
    np.maximum.accumulate(i1, out=i1)     # last non-255 position (reversed frame)
    np.subtract(idx, i1, out=i1)          # run length ending at i (reversed)
    ffrun = ws.ffrun[: n + 1]
    np.copyto(ffrun[:n], i1[::-1])
    np.multiply(ffrun[:n], b1, out=ffrun[:n])  # zero where byte != 255
    ffrun[n] = 0

    np.right_shift(ui, 4, out=i2)         # i2 = literal nibble
    np.equal(i2, 15, out=b2)              # b2 = has literal extension
    np.take(ffrun, idxp1, out=i3)         # i3 = r1 (255-run after token)
    np.add(idxp1, i3, out=i4)             # i4 = terminator position
    np.greater_equal(i4, n, out=b3)
    np.logical_and(b3, b2, out=b3)        # b3 = truncated literal length
    np.minimum(i4, n - 1, out=i4)
    np.take(ui, i4, out=i5)               # i5 = terminator byte
    np.multiply(i3, 255, out=i4)
    np.add(i4, i5, out=i4)
    np.add(i4, 15, out=i4)                # i4 = extended literal length
    lit_len = i5
    np.copyto(lit_len, i2)
    np.copyto(lit_len, i4, where=b2)      # i5 = lit_len
    lit_start = i4
    np.add(idx, 1, out=lit_start)
    np.add(lit_start, 1, out=i1)
    np.add(i1, i3, out=i1)
    np.copyto(lit_start, i1, where=b2)    # i4 = lit_start (token + header)
    ls_end = i1
    np.add(lit_start, lit_len, out=ls_end)  # i1 = offset-field position

    np.bitwise_and(ui, 15, out=i2)        # i2 = match nibble
    np.equal(i2, 15, out=b1)              # b1 = has match extension (b1 reused)
    np.minimum(ls_end, n - 1, out=i6)
    np.take(ui, i6, out=i7)               # low offset byte
    np.add(i6, 1, out=i6)
    np.minimum(i6, n - 1, out=i6)
    np.take(ui, i6, out=i8)
    np.left_shift(i8, 8, out=i8)
    np.bitwise_or(i7, i8, out=i7)         # i7 = offset (garbage if truncated)
    np.add(ls_end, 2, out=i6)             # i6 = ext-byte position
    np.minimum(i6, n, out=i3)
    np.take(ffrun, i3, out=i8)            # i8 = r2
    np.add(i6, i8, out=i6)                # i6 = match terminator position
    np.greater_equal(i6, n, out=b4)
    np.logical_and(b4, b1, out=b4)        # b4 = truncated match length
    np.minimum(i6, n - 1, out=i6)
    np.take(ui, i6, out=i3)               # i3 = terminator byte
    np.multiply(i8, 255, out=i6)
    np.add(i6, i3, out=i3)
    np.add(i3, 19, out=i3)                # i3 = extended match length
    mlen = i6
    np.add(i2, 4, out=mlen)
    np.copyto(mlen, i3, where=b1)         # i6 = match_len
    nxt = i2
    np.add(ls_end, 2, out=nxt)
    np.add(i8, 1, out=i8)
    np.add(nxt, i8, out=i3)
    np.copyto(nxt, i3, where=b1)          # i2 = next sequence position

    # Serial residue: hop the sequence chain.  For a valid final sequence
    # ls_end == n and nxt > n, so the walk exits on pos >= n either way;
    # headers are >= 1 byte, so nxt > pos and the walk always terminates.
    nxt_mv = memoryview(nxt)
    starts = []
    append = starts.append
    pos = 0
    while pos < n:
        append(pos)
        pos = nxt_mv[pos]

    T = np.asarray(starts, np.int64)
    ll = lit_len[T].astype(np.int64)
    ls_end_T = ls_end[T].astype(np.int64)
    final_ok = bool(ls_end_T[-1] == n)
    nonfinal = ls_end_T != n
    if not final_ok:
        # Chain left the block without a final literals-only sequence.  If
        # it ended exactly at n after a match, the serial decoders see a
        # missing token; field-level truncations are reported below.
        nonfinal[-1] = True
    ml = np.where(nonfinal, mlen[T].astype(np.int64), 0)
    off_T = i7[T].astype(np.int64)
    total = np.cumsum(ll + ml)
    before_match = total - ml      # output length after seq's literals
    prev_total = before_match - ll  # output length before the sequence

    # Vectorized validation, in the serial decoders' per-sequence order.
    err = np.zeros(len(T), np.int8)

    def _mark(cond, code):
        np.copyto(err, code, where=(err == 0) & cond)

    _mark(b3[T], 1)
    _mark(ls_end_T > n, 2)
    if max_out is not None:
        _mark(prev_total + ll > max_out, 3)
    _mark(nonfinal & (ls_end_T + 2 > n), 4)
    _mark(nonfinal & (off_T == 0), 5)
    _mark(nonfinal & (off_T > before_match), 6)
    _mark(nonfinal & b4[T], 7)
    if max_out is not None:
        _mark(nonfinal & (before_match + ml > max_out), 8)
    bad = np.nonzero(err)[0]
    if len(bad):
        raise LZ4FormatError(_ERR_MESSAGES[int(err[bad[0]])])
    if not final_ok:
        raise LZ4FormatError("truncated block: missing token")

    keep = ll > 0
    return BlockPlan(
        usize=int(total[-1]),
        lit_src=lit_start[T].astype(np.int64)[keep],
        lit_dst=prev_total[keep],
        lit_len=ll[keep],
        match_dst=before_match[nonfinal],
        match_src=before_match[nonfinal] - off_T[nonfinal],
        match_len=ml[nonfinal],
    )


def _span_fill(starts: np.ndarray, lens: np.ndarray, buf: np.ndarray) -> np.ndarray:
    """Fill ``buf`` with the flat indices covering every [start, start+len).

    Standard delta/cumsum expansion, O(total) with no Python loop, writing
    into a workspace buffer so repeated calls never fault fresh pages.  All
    ``lens`` must be > 0.  Returns the filled view.
    """
    total = int(lens.sum())
    v = buf[:total]
    v.fill(1)
    ends = np.cumsum(lens)
    v[0] = starts[0]
    if len(starts) > 1:
        v[ends[:-1]] = starts[1:] - starts[:-1] - lens[:-1] + 1
    np.cumsum(v, out=v)
    return v


def _finish_sequential(out: np.ndarray, d: np.ndarray, s: np.ndarray,
                       L: np.ndarray) -> None:
    """In-order chunked copies for the remaining matches, in bytes-land.

    Per-element NumPy slicing costs ~µs per match; for the typical 36-byte
    paper-capped match a memoryview slice copy is ~10x cheaper while large
    spans still move at memcpy speed.  Always correct (strict stream
    order), used when wave scheduling stops paying.
    """
    mv = memoryview(out)
    for dst, src, ln in zip(d.tolist(), s.tolist(), L.tolist()):
        off = dst - src
        if off >= ln:
            mv[dst:dst + ln] = mv[src:src + ln]
        else:
            pattern = bytes(mv[src:dst])
            reps = -(-ln // off)
            mv[dst:dst + ln] = (pattern * reps)[:ln]


def execute_plan(block: bytes, plan: BlockPlan, out: np.ndarray | None = None,
                 wave_limit: int = 8, min_wave: int = 256) -> np.ndarray:
    """Materialize a BlockPlan into a uint8 output array.

    ``out`` may be a caller-provided view of exactly ``plan.usize`` bytes
    (e.g. a disjoint slice of one preallocated output buffer; the decode
    engine currently returns per-block bytes instead, since its process
    executor must ship results across the pool anyway).

    Hybrid bulk execution, adaptively picking the cheaper mechanism:

      literals     — one fancy-index gather for ALL runs at once (span
                     expansion through the per-thread workspace), or a
                     memoryview copy loop when there are few runs;
      matches      — dependency *waves*: every match whose source bytes are
                     already materialized executes in one vectorized
                     gather/scatter per wave (readiness is an interval query
                     against the still-pending write intervals — pending
                     writes are disjoint and sorted, so two binary searches
                     per match).  Overlapping matches (offset < length)
                     replicate their pattern chunkwise; thin waves and
                     pathological chains fall back to in-order memoryview
                     copies after ``wave_limit`` waves (always correct).
    """
    if out is None:
        out = np.empty(plan.usize, np.uint8)
    elif len(out) != plan.usize:
        raise ValueError(f"out buffer is {len(out)} bytes, plan needs {plan.usize}")
    if plan.usize == 0:
        return out
    ws_ok = plan.usize <= _PlanWorkspace.CAP
    # Phase 1: literals.
    nlit = len(plan.lit_len)
    if nlit >= 64 and ws_ok:
        ws = _workspace()
        blk = np.frombuffer(block, np.uint8)
        src_v = _span_fill(plan.lit_src, plan.lit_len, ws.span_a)
        dst_v = _span_fill(plan.lit_dst, plan.lit_len, ws.span_b)
        np.take(blk, src_v, out=ws.u8tmp[: len(src_v)])
        out[dst_v] = ws.u8tmp[: len(src_v)]
    elif nlit:
        mv = memoryview(out)
        src_mv = memoryview(block)
        for dst, src, ln in zip(plan.lit_dst.tolist(), plan.lit_src.tolist(),
                                plan.lit_len.tolist()):
            mv[dst:dst + ln] = src_mv[src:src + ln]
    # Phase 2: match copies in dependency waves.
    d, s, L = plan.match_dst, plan.match_src, plan.match_len
    if not len(d):
        return out
    pend = np.arange(len(d))
    waves = 0
    while pend.size:
        if waves >= wave_limit or not ws_ok:
            _finish_sequential(out, d[pend], s[pend], L[pend])
            break
        dp, sp, Lp = d[pend], s[pend], L[pend]
        dep = dp + Lp
        # A pending match needs [sp, min(sp+Lp, dp)) materialized before it
        # can run (bytes at/after its own dst are produced by the copy
        # itself — that is the overlap-replication case, handled below).
        need_end = np.minimum(sp + Lp, dp)
        lo = np.searchsorted(dep, sp, side="right")
        hi = np.searchsorted(dp, need_end, side="left")
        ready = lo >= hi
        sel_size = int(ready.sum())
        if sel_size < min_wave and sel_size < pend.size:
            # Thin wave: vectorization overhead beats the win; finish in order.
            _finish_sequential(out, d[pend], s[pend], L[pend])
            break
        ds, ss, Ls = dp[ready], sp[ready], Lp[ready]
        overlap = (ds - ss) < Ls
        if overlap.any():
            # Overlap-ready matches are mutually independent (their reads
            # hit only materialized bytes), so subset order is free.
            _finish_sequential(out, ds[overlap], ss[overlap], Ls[overlap])
        plain = ~overlap
        if plain.any():
            dsp, ssp, lsp = ds[plain], ss[plain], Ls[plain]
            if dsp.size < 64:
                _finish_sequential(out, dsp, ssp, lsp)
            else:
                ws = _workspace()
                src_v = _span_fill(ssp, lsp, ws.span_a)
                dst_v = _span_fill(dsp, lsp, ws.span_b)
                np.take(out, src_v, out=ws.u8tmp[: len(src_v)])
                out[dst_v] = ws.u8tmp[: len(src_v)]
        pend = pend[~ready]
        waves += 1
    return out


# ---------------------------------------------------------------------------
# Fixed-shape device plans (the jit-consumable form of BlockPlan)
# ---------------------------------------------------------------------------

# ceil(log2(MAX_BLOCK)): after this many pointer-doubling rounds every
# source chain in a <= 64 KB output is resolved (chain positions strictly
# decrease, so depth < 2^16), for ANY valid plan.  The static worst case.
MAX_RESOLVE_ROUNDS = 16


class DevicePlanOverflow(ValueError):
    """Plan does not fit the fixed-shape caps; caller should fall back to
    host execution for this block (the decode engine does, and counts it)."""


@dataclasses.dataclass(frozen=True)
class DevicePlanCaps:
    """Static array sizes for `DevicePlan` (= compiled-shape axes).

    Defaults are sized for the paper scheme the compress engine emits: one
    match per `pws`-byte window caps matches at MAX_BLOCK/8 = 8192 (plus
    one literal run per match + the final run), padded up for lane
    alignment.  Foreign LZ4 blocks can legally exceed this (down to 4-byte
    matches back to back — up to 16384); they overflow and decode on host.
    """

    max_lit: int = 8448      # literal-span slots (engine scheme: <= 8193)
    max_match: int = 8448    # match slots (engine scheme: <= 8192)
    blk_cap: int = 65536     # compressed-payload buffer (csize <= usize)
    out_cap: int = 65536     # decoded-output buffer (usize <= MAX_BLOCK)


_DEFAULT_CAPS = DevicePlanCaps()


@dataclasses.dataclass
class DevicePlan:
    """Fixed-shape `BlockPlan`: flat int32 arrays padded to `caps` sizes.

    Rows past `n_lit` / `n_match` are zero padding and must be ignored
    (the device graph masks them by slot index, not by sentinel values).
    ``wave[m]`` is the pointer-doubling round at which match m's bytes are
    fully resolved (see module docstring); ``n_waves`` is the block's max —
    the number of on-device gather rounds this plan actually needs.  When
    the converter is asked to skip wave analysis, ``wave`` is -1 and
    ``n_waves`` is the static worst case `MAX_RESOLVE_ROUNDS`.
    """

    caps: DevicePlanCaps
    lit_src: np.ndarray    # (max_lit,) int32 — source offset in the block
    lit_dst: np.ndarray    # (max_lit,) int32 — dest offset in the output
    lit_len: np.ndarray    # (max_lit,) int32
    match_dst: np.ndarray  # (max_match,) int32
    match_off: np.ndarray  # (max_match,) int32 — back-offset (dst - src)
    match_len: np.ndarray  # (max_match,) int32
    wave: np.ndarray       # (max_match,) int32 — resolve round (or -1)
    n_lit: int
    n_match: int
    out_size: int
    n_waves: int

    @property
    def n_sequences(self) -> int:
        return self.n_lit + self.n_match


def _expand_spans(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat indices covering every [start, start+len) — fresh-array twin of
    `_span_fill` for the conversion path (not perf-critical there)."""
    total = int(lens.sum())
    v = np.ones(total, np.int64)
    ends = np.cumsum(lens)
    v[0] = starts[0]
    if len(starts) > 1:
        v[ends[:-1]] = starts[1:] - starts[:-1] - lens[:-1] + 1
    np.cumsum(v, out=v)
    return v


def _byte_sources(plan: BlockPlan):
    """Per-output-byte immediate source maps (the device layout, in NumPy).

    Returns ``(is_lit, lit_blk, ptr)`` over ``[0, plan.usize)``:
    ``is_lit[k]`` marks bytes produced by a literal run, ``lit_blk[k]`` is
    their source index in the compressed block, and ``ptr[k]`` is one
    application of the source function f — k itself for literal bytes
    (fixed point), ``k - offset`` for match bytes.
    """
    usize = plan.usize
    is_lit = np.zeros(usize, bool)
    lit_blk = np.zeros(usize, np.int64)
    ptr = np.arange(usize, dtype=np.int64)
    if len(plan.lit_len):
        dst_v = _expand_spans(plan.lit_dst, plan.lit_len)
        is_lit[dst_v] = True
        lit_blk[dst_v] = _expand_spans(plan.lit_src, plan.lit_len)
    if len(plan.match_len):
        md_v = _expand_spans(plan.match_dst, plan.match_len)
        off_v = np.repeat(plan.match_dst - plan.match_src, plan.match_len)
        ptr[md_v] = md_v - off_v
    return is_lit, lit_blk, ptr


def _resolve_rounds(is_lit: np.ndarray, ptr: np.ndarray):
    """Run pointer doubling to a fixed point; returns (ptr_resolved, round
    at which each byte resolved).  Bounded by MAX_RESOLVE_ROUNDS."""
    rounds = np.zeros(len(ptr), np.int32)
    resolved = is_lit[ptr] if len(ptr) else np.zeros(0, bool)
    r = 0
    while not resolved.all():
        r += 1
        assert r <= MAX_RESOLVE_ROUNDS, "unresolvable source chain"
        ptr = ptr[ptr]
        newly = is_lit[ptr] & ~resolved
        rounds[newly] = r
        resolved |= newly
    return ptr, rounds


def execute_device_plan(block: bytes, plan: BlockPlan) -> np.ndarray:
    """NumPy oracle of the DEVICE decode algorithm (`kernels.ops.decode_gather`).

    Same result as `execute_plan`, different mechanism: build the per-byte
    immediate-source maps, pointer-double to transitive literal sources,
    then materialize the whole output with ONE gather from the block.  The
    tests pin `execute_plan` == this == the jnp fallback == the Pallas
    kernel, so the device graph has an explicit host twin.
    """
    if plan.usize == 0:
        return np.zeros(0, np.uint8)
    is_lit, lit_blk, ptr = _byte_sources(plan)
    ptr, _ = _resolve_rounds(is_lit, ptr)
    blk = np.frombuffer(block, np.uint8)
    return blk[lit_blk[ptr]]


def to_device_plan(plan: BlockPlan, caps: DevicePlanCaps | None = None,
                   compute_waves: bool = True) -> DevicePlan:
    """`BlockPlan` -> fixed-shape `DevicePlan` (raises `DevicePlanOverflow`
    when the plan exceeds ``caps``).

    ``compute_waves=True`` runs the host doubling analysis to fill the
    per-sequence ``wave`` index and the exact ``n_waves`` — O(usize·rounds)
    NumPy work that lets the decode engine dispatch shallow micro-batches
    with fewer on-device gather rounds.  ``False`` skips the analysis and
    pins ``n_waves`` to the always-correct `MAX_RESOLVE_ROUNDS`.
    """
    caps = caps or _DEFAULT_CAPS
    n_lit = len(plan.lit_len)
    n_match = len(plan.match_len)
    if n_lit > caps.max_lit:
        raise DevicePlanOverflow(
            f"{n_lit} literal runs exceed cap {caps.max_lit}")
    if n_match > caps.max_match:
        raise DevicePlanOverflow(
            f"{n_match} matches exceed cap {caps.max_match}")
    if plan.usize > caps.out_cap:
        raise DevicePlanOverflow(
            f"output size {plan.usize} exceeds cap {caps.out_cap}")

    def _pad(values: np.ndarray, cap: int) -> np.ndarray:
        out = np.zeros(cap, np.int32)
        out[: len(values)] = values
        return out

    wave = np.full(caps.max_match, -1, np.int32)
    n_waves = MAX_RESOLVE_ROUNDS
    if compute_waves:
        if plan.usize == 0:
            n_waves = 0
        else:
            is_lit, _, ptr = _byte_sources(plan)
            _, rounds = _resolve_rounds(is_lit, ptr)
            n_waves = int(rounds.max())
            if n_match:
                md_v = _expand_spans(plan.match_dst, plan.match_len)
                bounds = np.concatenate(
                    ([0], np.cumsum(plan.match_len)[:-1]))
                wave[:n_match] = np.maximum.reduceat(rounds[md_v], bounds)
    return DevicePlan(
        caps=caps,
        lit_src=_pad(plan.lit_src, caps.max_lit),
        lit_dst=_pad(plan.lit_dst, caps.max_lit),
        lit_len=_pad(plan.lit_len, caps.max_lit),
        match_dst=_pad(plan.match_dst, caps.max_match),
        match_off=_pad(plan.match_dst - plan.match_src, caps.max_match),
        match_len=_pad(plan.match_len, caps.max_match),
        wave=wave,
        n_lit=n_lit,
        n_match=n_match,
        out_size=plan.usize,
        n_waves=n_waves,
    )


def decode_block_planned(block: bytes, max_out: int | None = None,
                         fast: bool = True) -> bytes:
    """plan + execute; bit-identical to `decode_block`.

    ``fast=False`` forces the serial-parse planner (the reference the
    vectorized planner is tested against).
    """
    planner = plan_block_fast if fast else plan_block
    plan = planner(block, max_out=max_out)
    return execute_plan(block, plan).tobytes()
