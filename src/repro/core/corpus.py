"""Deterministic Calgary-substitute corpus.

The Calgary corpus cannot be redistributed in this offline container, so we
synthesize a corpus with the same *kinds* of redundancy (English-like text,
program sources, structured records, bitmaps, near-random binary).  All files
are generated from fixed seeds — every run sees identical bytes.  The
reproduction target is the paper's *attenuation percentages* (ratio of
ratios), which are far less corpus-sensitive than absolute ratios; see
DESIGN.md §7.
"""
from __future__ import annotations

import functools

import numpy as np

_WORDS = (
    "the of and a to in is was he for it with as his on be at by i this had "
    "not are but from or have an they which one you were her all she there "
    "would their we him been has when who will more no if out so said what "
    "up its about into than them can only other new some could time these "
    "two may then do first any my now such like our over man me even most "
    "made after also did many before must through back years where much your "
    "way well down should because each just those people mr how too little "
    "state good very make world still own see men work long get here between "
    "both life being under never day same another know while last might us "
    "great old year off come since against go came right used take three"
).split()

_C_KEYWORDS = (
    "int", "char", "float", "double", "void", "return", "if", "else", "for",
    "while", "struct", "static", "const", "unsigned", "long", "switch",
    "case", "break", "continue", "sizeof", "typedef", "enum", "extern",
)


def _text_like(rng: np.random.Generator, size: int) -> bytes:
    """Zipf-weighted English-like prose with sentence/paragraph structure."""
    ranks = np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    out = []
    total = 0
    sentence_len = 0
    while total < size:
        w = _WORDS[rng.choice(len(_WORDS), p=probs)]
        if sentence_len == 0:
            w = w.capitalize()
        out.append(w)
        total += len(w) + 1
        sentence_len += 1
        if sentence_len >= rng.integers(6, 18):
            out[-1] += "." if rng.random() < 0.8 else "?"
            sentence_len = 0
            if rng.random() < 0.12:
                out[-1] += "\n\n"
    return (" ".join(out)[:size]).encode("latin-1")


def _code_like(rng: np.random.Generator, size: int) -> bytes:
    """C-like source: repeated identifiers, indentation, boilerplate."""
    idents = [f"var_{i}" for i in range(40)] + [f"fn_{i}" for i in range(20)]
    lines = []
    total = 0
    while total < size:
        kind = rng.random()
        if kind < 0.25:
            ln = f"{rng.choice(_C_KEYWORDS)} {rng.choice(idents)} = {rng.integers(0, 1000)};"
        elif kind < 0.5:
            ln = f"    {rng.choice(idents)} = {rng.choice(idents)} + {rng.choice(idents)};"
        elif kind < 0.7:
            ln = f"if ({rng.choice(idents)} > {rng.integers(0, 100)}) {{"
        elif kind < 0.85:
            ln = f"    return {rng.choice(idents)};"
        else:
            ln = "}"
        lines.append(ln)
        total += len(ln) + 1
    return ("\n".join(lines)[:size]).encode("latin-1")


def _records_like(rng: np.random.Generator, size: int) -> bytes:
    """bib/trans-like structured records with repeated field tags."""
    fields = ["%A ", "%T ", "%J ", "%D ", "%V ", "%P ", "%I "]
    out = []
    total = 0
    rec = 0
    while total < size:
        rec += 1
        for f in fields:
            words = " ".join(rng.choice(_WORDS, size=rng.integers(2, 7)))
            ln = f + words.title()
            out.append(ln)
            total += len(ln) + 1
        out.append("")
        total += 1
    return ("\n".join(out)[:size]).encode("latin-1")


def _bitmap_like(rng: np.random.Generator, size: int) -> bytes:
    """pic-like: long runs of 0x00 with occasional strokes."""
    buf = np.zeros(size, dtype=np.uint8)
    n_strokes = size // 200
    starts = rng.integers(0, size, n_strokes)
    lens = rng.integers(1, 24, n_strokes)
    vals = rng.integers(1, 256, n_strokes)
    for s, l, v in zip(starts, lens, vals):
        buf[s : s + l] = v
    return buf.tobytes()


def _geo_like(rng: np.random.Generator, size: int) -> bytes:
    """geo-like: correlated 32-bit samples (smooth seismic-ish signal)."""
    n = size // 4 + 1
    steps = rng.normal(0, 80.0, n)
    sig = np.cumsum(steps).astype(np.int32)
    return sig.tobytes()[:size]


def _markov_binary(rng: np.random.Generator, size: int, alphabet: int = 64) -> bytes:
    """obj-like: byte stream from a skewed Markov chain (moderate entropy)."""
    trans = rng.dirichlet(np.full(alphabet, 0.06), size=alphabet)
    cum = np.cumsum(trans, axis=1)
    out = np.empty(size, dtype=np.uint8)
    state = 0
    u = rng.random(size)
    for i in range(size):
        state = int(np.searchsorted(cum[state], u[i]))
        out[i] = state
    return out.tobytes()


def _random_bytes(rng: np.random.Generator, size: int) -> bytes:
    """Nearly incompressible."""
    return rng.integers(0, 256, size, dtype=np.uint8).tobytes()


_SPEC = [
    # (name, generator, size)  — sizes chosen so the full corpus is ~1.2 MB,
    # keeping golden-model sweeps tractable on one CPU core.
    ("bib", _records_like, 108 * 1024),
    ("book1", _text_like, 196 * 1024),
    ("book2", _text_like, 152 * 1024),
    ("geo", _geo_like, 102 * 1024),
    ("news", _text_like, 120 * 1024),
    ("obj1", _markov_binary, 21 * 1024),
    ("obj2", _markov_binary, 96 * 1024),
    ("paper1", _text_like, 53 * 1024),
    ("paper2", _text_like, 82 * 1024),
    ("pic", _bitmap_like, 160 * 1024),
    ("progc", _code_like, 39 * 1024),
    ("progl", _code_like, 71 * 1024),
    ("progp", _code_like, 49 * 1024),
    ("trans", _records_like, 93 * 1024),
]


@functools.lru_cache(maxsize=4)
def corpus_files(seed: int = 20240325) -> dict[str, bytes]:
    """The deterministic 14-file corpus (name -> bytes)."""
    files = {}
    for i, (name, gen, size) in enumerate(_SPEC):
        rng = np.random.Generator(np.random.PCG64(seed + i * 1009))
        files[name] = gen(rng, size)
        assert len(files[name]) == size, name
    return files


def corpus_blocks(files: dict[str, bytes] | None = None, block: int = 65536) -> list[bytes]:
    """All corpus files split into independent <=64 KB blocks (paper's framing)."""
    files = corpus_files() if files is None else files
    blocks = []
    for data in files.values():
        for i in range(0, len(data), block):
            blocks.append(data[i : i + block])
    return blocks
