"""Vectorized JAX engine of the paper's combined scheme (S1 + S2).

This is the TPU-native re-expression of the hardware architecture in Fig. 5:

  Word Shift + Hash Calculation  -> kernels.ops.hash_positions (Pallas/jnp)
  Hash Table (LVT, multi-port)   -> candidate resolution: because every
        position is written every cycle and reads see previous-cycle
        state, cand(p) = max{q : hash(q)=hash(p), window(q)<window(p)} — a
        per-bucket predecessor query.  Four bit-identical impls
        (`candidate_impl`): "sort" (argsort + segment ops), "sortkey"
        (packed-key sort), "scatter" (scatter-max + log-depth cummax, no
        sort), and "fused" (the whole hash->LVT->match-extend datapath as
        ONE Pallas kernel with a VMEM-resident table written/read in
        window order — kernels/fused_compress.py; jnp twin
        kernels/ref.fused_ref).  "auto" (the default) resolves per
        backend (`resolve_candidate_impl`): the measured-fastest impl on
        CPU, the expected accelerator shapes off-CPU.
  Match Searching                -> vectorized word compare (the table stores
        the 4-byte string; here: words[cand] == words[p])
  Extended Match (bounded, S2)   -> kernels.ops.match_lengths (fixed-depth)
  single-match select (S1)       -> per-window earliest-eligible selection.
        The only true sequential state is the free pointer; S2 bounds its
        reach to max_match-1 bytes, so it admits BOTH
          * a paper-faithful `lax.scan` over windows (1 "cycle"/window), and
          * an associative scan over per-window transfer tables of size
            R = max_match (beyond-paper optimization: O(log W) depth).
  Sequence Encoding              -> exact compressed size computed in-graph;
        byte emission ALSO stays in-graph on the default engine path
        (`compress_block_bytes` -> kernels.ops.emit_bytes: prefix-sum
        offsets + byte scatter on device, only final bytes cross the host
        boundary).  The host-side emitters (emitter.py vectorized,
        encoder.py loop-based) survive as the bit-identity oracles.

All variants are bit-identical to the numpy golden model (schemes.py) and to
each other; tests/test_lz4_jax.py asserts exact equality of the per-window
match records, tests/test_device_emit.py the emitted bytes.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .lz4_types import (
    DEFAULT_HASH_BITS,
    DEFAULT_MAX_MATCH,
    DEFAULT_PWS,
    LAST_LITERALS,
    MAX_BLOCK,
    MF_LIMIT,
    MIN_MATCH,
    Sequence,
)

_PAD = 71  # block padding: max max_match (68) + 3 word-shift bytes

# The candidate-resolution implementations selectable via `candidate_impl`
# (all bit-identical at the match-record level; tests/test_lz4_jax.py,
# tests/test_fused_compress.py).
CANDIDATE_IMPLS = ("sort", "sortkey", "scatter", "fused")


def resolve_candidate_impl(candidate_impl: str = "auto",
                           backend: str | None = None,
                           use_pallas: bool = False) -> str:
    """Resolve ``"auto"`` to the best impl for a backend.

    On CPU the choice is MEASURED (BENCH_engine_batched.json
    `candidate_impl`, docs/tuning.md): the packed-key value sort wins
    (~1.4x over argsort at micro_batch=32 — half the sort payload, no gathers; it also beats
    scatter's 8 MB grid at every micro-batch on the reference container).
    Off CPU the choices are the expected accelerator shapes, not yet
    benchmarked on real hardware: the scatter-max formulation (log-depth
    cummax, no sort) on GPU and on TPU without Pallas; with
    ``use_pallas=True`` on TPU, the fused single-pass kernel that keeps
    the whole datapath in VMEM.  "fused" is only auto-selected when the
    Pallas kernel would actually run — its jnp twin is the scatter
    formulation plus extra gathers, so auto-picking it without Pallas
    would be strictly worse than "scatter".  Concrete impl names pass
    through unchanged, so callers can always pin one.
    """
    if candidate_impl == "auto":
        backend = backend or jax.default_backend()
        if backend == "tpu":
            return "fused" if use_pallas else "scatter"
        return "scatter" if backend == "gpu" else "sortkey"
    if candidate_impl not in CANDIDATE_IMPLS:
        raise ValueError(
            f"candidate_impl must be 'auto' or one of {CANDIDATE_IMPLS}, "
            f"got {candidate_impl!r}"
        )
    return candidate_impl

# Device-emit output buffer size per block.  The worst case compressed block
# is literals-only: 1 token + 257 extension bytes + MAX_BLOCK literals =
# MAX_BLOCK + 258; padded up to a lane-aligned multiple of the emit kernel's
# tile (2048) so the Pallas path needs no re-padding.
OUT_CAP = MAX_BLOCK + 2048


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockRecords:
    """Per-window match records for one block — the hardware's output signals."""

    emit: jax.Array     # (W,) bool
    pos: jax.Array      # (W,) int32
    length: jax.Array   # (W,) int32
    offset: jax.Array   # (W,) int32
    size: jax.Array     # () int32 — exact compressed size of the block


def _candidates_scatter(hashes, n, hash_bits: int, pws: int):
    """Scatter-max LVT candidate resolution (beyond-paper optimization).

    cand(p) = max{q : hash(q)=hash(p), win(q)<win(p)} computed WITHOUT the
    64K-element argsort: scatter-max positions into a (windows x entries)
    grid (this IS the hash table, materialized over time), exclusive cummax
    along the window axis (log-depth), then gather at (win(p), hash(p)).
    Identical output to _candidates; ~2.5x less memory traffic (see
    EXPERIMENTS.md §Perf).  The formulation itself lives in
    `kernels.ref.scatter_candidates_ref` — it is also stage 2 of the fused
    datapath's jnp twin, and sharing one definition keeps the staged impl
    and the twin from drifting.
    """
    from repro.kernels.ref import scatter_candidates_ref

    return scatter_candidates_ref(hashes, n, hash_bits, pws)


def _candidates_sortkey(hashes, n, hash_bits: int, pws: int):
    """Key-packed sort candidate resolution (beyond-paper optimization).

    Because P = 65536 = 2^16, (hash, position) packs into ONE int32 key:
    `h << 16 | p`.  Sorting values (jnp.sort) instead of argsort halves the
    sort payload (no index array to permute) and eliminates the two gathers
    that argsort-based resolution needs; both hash and position are recovered
    from the sorted key by bit ops.  Bit-identical to _candidates.
    """
    P = hashes.shape[0]
    assert P & (P - 1) == 0, "key packing requires power-of-two P"
    p = jnp.arange(P, dtype=jnp.int32)
    valid_pos = p <= n - MIN_MATCH
    h = jnp.where(valid_pos, hashes, 1 << hash_bits)
    skey = jnp.sort(h * P + p)
    h_s = skey >> 16
    p_s = skey & (P - 1)
    w_s = p_s // pws
    prev_h = jnp.concatenate([jnp.full((1,), -1, h_s.dtype), h_s[:-1]])
    prev_w = jnp.concatenate([jnp.full((1,), -1, w_s.dtype), w_s[:-1]])
    prev_p = jnp.concatenate([jnp.full((1,), -1, p_s.dtype), p_s[:-1]])
    same_hash = h_s == prev_h
    head = ~(same_hash & (w_s == prev_w))
    group_id = jnp.cumsum(head.astype(jnp.int32)) - 1
    head_cand = jnp.where(head & same_hash, prev_p, -1)
    group_val = jnp.zeros((P,), jnp.int32).at[group_id].add(
        jnp.where(head, head_cand + 1, 0)
    )
    cand_s = jnp.take(group_val, group_id) - 1
    cand = jnp.zeros((P,), jnp.int32).at[p_s].set(cand_s)
    return cand


def _candidates(hashes, n, hash_bits: int, pws: int):
    """Sort-based LVT candidate resolution. hashes: (P,) int32."""
    P = hashes.shape[0]
    p = jnp.arange(P, dtype=jnp.int32)
    # Positions without a full 4-byte word get a sentinel bucket so they can
    # neither find nor become candidates.
    valid_pos = p <= n - MIN_MATCH
    h = jnp.where(valid_pos, hashes, 1 << hash_bits)
    key = h * P + p  # unique; sorts by (hash, position)
    order = jnp.argsort(key).astype(jnp.int32)
    h_s = jnp.take(h, order)
    w_s = order // pws
    prev_h = jnp.concatenate([jnp.full((1,), -1, h_s.dtype), h_s[:-1]])
    prev_w = jnp.concatenate([jnp.full((1,), -1, w_s.dtype), w_s[:-1]])
    prev_p = jnp.concatenate([jnp.full((1,), -1, order.dtype), order[:-1]])
    same_hash = h_s == prev_h
    head = ~(same_hash & (w_s == prev_w))
    group_id = jnp.cumsum(head.astype(jnp.int32)) - 1
    head_cand = jnp.where(head & same_hash, prev_p, -1)
    # Each group has exactly one head: scatter head candidate, gather back.
    group_val = jnp.zeros((P,), jnp.int32).at[group_id].add(
        jnp.where(head, head_cand + 1, 0)
    )
    cand_s = jnp.take(group_val, group_id) - 1
    cand = jnp.zeros((P,), jnp.int32).at[order].set(cand_s)
    return cand


def _select_sequential(valid, lengths, pws: int):
    """Paper-faithful window scan: one step per window, free-pointer carry."""
    P = valid.shape[0]
    W = P // pws
    validw = valid.reshape(W, pws)
    lenw = lengths.reshape(W, pws)
    base = (jnp.arange(W, dtype=jnp.int32) * pws)[:, None]
    posw = base + jnp.arange(pws, dtype=jnp.int32)[None, :]

    def step(fp, xs):
        v, l, pos = xs
        elig = v & (pos >= fp)
        any_e = elig.any()
        idx = jnp.argmax(elig)
        sel_pos = pos[idx]
        sel_len = l[idx]
        fp2 = jnp.where(any_e, sel_pos + sel_len, fp)
        return fp2, (any_e, sel_pos, sel_len)

    _, (emit, pos, length) = jax.lax.scan(step, jnp.int32(0), (validw, lenw, posw))
    return emit, pos, length


def _select_associative(valid, lengths, pws: int, max_match: int):
    """Beyond-paper: compose per-window free-pointer transfer tables.

    S2 bounds the free pointer entering window w to [ws, ws + R) with
    R = max_match (fp' = p + len <= ws-1 + max_match).  Each window is a
    monotone step-function on R states; composition is associative, so the
    whole selection runs in O(log W) depth.
    """
    P = valid.shape[0]
    W = P // pws
    R = max_match  # entering fp - window_start is in [0, R)
    validw = valid.reshape(W, pws)
    lenw = lengths.reshape(W, pws)
    base = jnp.arange(W, dtype=jnp.int32)[:, None] * pws
    rel = jnp.arange(pws, dtype=jnp.int32)[None, :]

    # Transfer table: for entering fp = ws + r, the resulting absolute fp'.
    r = jnp.arange(R, dtype=jnp.int32)[None, :, None]           # (1, R, 1)
    elig = validw[:, None, :] & (rel[:, None, :] >= r)           # (W, R, pws)
    any_e = elig.any(-1)                                         # (W, R)
    idx = jnp.argmax(elig, axis=-1).astype(jnp.int32)            # (W, R)
    sel_end = base + idx + jnp.take_along_axis(lenw, idx, axis=-1)
    table = jnp.where(any_e, sel_end, base + jnp.arange(R, dtype=jnp.int32)[None, :])

    def compose(t1, t2):
        # Apply t1 (earlier windows) then t2.  Tables are indexed by the
        # entering fp relative to the composite's own base, so the composite
        # keeps t1's base.  Exit fp of t1 is < base2 + R (S2 bound), so the
        # clip below is exact, not an approximation.
        tab1, base1 = t1
        tab2, base2 = t2
        r2 = jnp.clip(tab1 - base2, 0, R - 1)
        return jnp.take_along_axis(tab2, r2, axis=-1), base1

    bases = jnp.arange(W, dtype=jnp.int32)[:, None] * pws  # (W,1) broadcast vs (W,R)
    bases = jnp.broadcast_to(bases, (W, R))
    prefix_tab, _ = jax.lax.associative_scan(compose, (table, bases), axis=0)
    # Entering fp for window w = prefix over [0..w-1] evaluated at r=0.
    entering = jnp.concatenate([jnp.zeros((1,), jnp.int32), prefix_tab[:-1, 0]])
    # Reconstruct the selection for every window in parallel.
    rw = jnp.clip(entering[:, None] - base, 0, R - 1)  # (W,1)
    elig_w = validw & (rel >= rw)
    emit = elig_w.any(-1)
    idxw = jnp.argmax(elig_w, axis=-1).astype(jnp.int32)
    pos = (base + idxw[:, None])[:, 0]
    length = jnp.take_along_axis(lenw, idxw[:, None], axis=-1)[:, 0]
    return emit, pos, length


def _lit_ext(x):
    return jnp.where(x < 15, 0, 1 + (x - 15) // 255)


def _match_ext(l):
    m = l - MIN_MATCH
    return jnp.where(m < 15, 0, 1 + (m - 15) // 255)


def _plan_size(emit, pos, length, n):
    """Exact compressed size from per-window match records (in-graph)."""
    end = jnp.where(emit, pos + length, 0)
    run_end = jax.lax.cummax(end)
    prev_end = jnp.concatenate([jnp.zeros((1,), jnp.int32), run_end[:-1]])
    lit = pos - prev_end
    per = jnp.where(emit, 1 + _lit_ext(lit) + lit + 2 + _match_ext(length), 0)
    last_end = run_end[-1]
    final_lit = n - last_end
    total = per.sum() + 1 + _lit_ext(final_lit) + final_lit
    return total.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "hash_bits", "max_match", "pws", "use_pallas", "scan_impl", "candidate_impl",
    ),
)
def compress_block_records(
    block_u8,
    n,
    hash_bits: int = DEFAULT_HASH_BITS,
    max_match: int = DEFAULT_MAX_MATCH,
    pws: int = DEFAULT_PWS,
    use_pallas: bool = False,
    scan_impl: str = "sequential",
    candidate_impl: str = "auto",
) -> BlockRecords:
    """Compress one padded block; returns per-window match records + size.

    block_u8 : (MAX_BLOCK + _PAD,) uint8 (content beyond `n` is ignored)
    n        : scalar int32 true length (0 <= n <= MAX_BLOCK)
    """
    assert block_u8.shape[0] == MAX_BLOCK + _PAD, block_u8.shape
    candidate_impl = resolve_candidate_impl(candidate_impl,
                                            use_pallas=use_pallas)
    block = block_u8.astype(jnp.int32)
    # Zero the padding region so it can never fake matches past n.
    idx = jnp.arange(block.shape[0], dtype=jnp.int32)
    block = jnp.where(idx < n, block, 0)

    p = jnp.arange(MAX_BLOCK, dtype=jnp.int32)
    if candidate_impl == "fused":
        # Single-pass datapath: hash, LVT candidate, word compare, and the
        # bounded extension come back from ONE kernel (or its jnp twin) —
        # no intermediate hash/word/candidate arrays round-trip through
        # the graph, and no sort anywhere.
        cand, lengths = ops.fused_match_candidates(
            block, n, positions=MAX_BLOCK, hash_bits=hash_bits, pws=pws,
            max_match=max_match, use_pallas=use_pallas,
        )
        valid = lengths >= MIN_MATCH
    else:
        words, hashes = ops.hash_positions(block[: MAX_BLOCK + 3], hash_bits, use_pallas=use_pallas)
        cand_fn = {
            "sort": _candidates,
            "sortkey": _candidates_sortkey,
            "scatter": _candidates_scatter,
        }[candidate_impl]
        cand = cand_fn(hashes, n, hash_bits, pws)

        has_cand = cand >= 0
        wc = jnp.take(words, jnp.clip(cand, 0, MAX_BLOCK - 1))
        valid4 = has_cand & (wc == words) & (p <= n - MF_LIMIT)

        lengths = ops.match_lengths(block, cand, valid4, n, max_match=max_match, use_pallas=use_pallas)
        valid = valid4 & (lengths >= MIN_MATCH)

    if scan_impl == "sequential":
        emit, pos, length = _select_sequential(valid, lengths, pws)
    elif scan_impl == "associative":
        emit, pos, length = _select_associative(valid, lengths, pws, max_match)
    else:
        raise ValueError(scan_impl)

    offset = pos - jnp.take(cand, pos)
    emit = emit & (length > 0)
    size = _plan_size(emit, pos, length, n)
    return BlockRecords(
        emit=emit,
        pos=jnp.where(emit, pos, -1),
        length=jnp.where(emit, length, 0),
        offset=jnp.where(emit, offset, 0),
        size=size,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "hash_bits", "max_match", "pws", "use_pallas", "scan_impl",
        "candidate_impl", "out_cap",
    ),
)
def compress_block_bytes(
    block_u8,
    n,
    hash_bits: int = DEFAULT_HASH_BITS,
    max_match: int = DEFAULT_MAX_MATCH,
    pws: int = DEFAULT_PWS,
    use_pallas: bool = False,
    scan_impl: str = "sequential",
    candidate_impl: str = "auto",
    out_cap: int = OUT_CAP,
):
    """Compress one padded block to FINAL BYTES, entirely in-graph.

    The device-resident emit path (docs/architecture.md §write path): the
    match-record pipeline of `compress_block_records` feeds straight into
    `kernels.ops.emit_bytes` — token byte-lengths, exclusive prefix-sum
    offsets, and the byte scatter all stay on the accelerator, so the ONLY
    host transfer per block is the (out_cap,) uint8 output buffer plus a
    size scalar (vs four (W,) record arrays for the host-emit path).

    Returns ``(out, size)``: out is (out_cap,) uint8, ``out[:size]`` is the
    compressed block, bit-identical to the host oracle
    ``emitter.emit_block(...)`` on the same records.
    """
    rec = compress_block_records(
        block_u8, n,
        hash_bits=hash_bits, max_match=max_match, pws=pws,
        use_pallas=use_pallas, scan_impl=scan_impl,
        candidate_impl=candidate_impl,
    )
    block = block_u8.astype(jnp.int32)
    idx = jnp.arange(block.shape[0], dtype=jnp.int32)
    block = jnp.where(idx < n, block, 0)
    out, total = ops.emit_bytes(
        block, rec.emit, rec.pos, rec.length, rec.offset, n,
        out_cap=out_cap, use_pallas=use_pallas,
    )
    return out, total


# Batched form for throughput: vmap over a stack of blocks.
@functools.partial(
    jax.jit,
    static_argnames=(
        "hash_bits", "max_match", "pws", "use_pallas", "scan_impl", "candidate_impl",
    ),
)
def compress_blocks_records(
    blocks_u8,
    ns,
    hash_bits: int = DEFAULT_HASH_BITS,
    max_match: int = DEFAULT_MAX_MATCH,
    pws: int = DEFAULT_PWS,
    use_pallas: bool = False,
    scan_impl: str = "sequential",
    candidate_impl: str = "auto",
) -> BlockRecords:
    fn = functools.partial(
        compress_block_records,
        hash_bits=hash_bits,
        max_match=max_match,
        pws=pws,
        use_pallas=use_pallas,
        scan_impl=scan_impl,
        candidate_impl=candidate_impl,
    )
    return jax.vmap(fn)(blocks_u8, ns)


def pad_block(data: bytes) -> tuple[np.ndarray, int]:
    buf = np.zeros(MAX_BLOCK + _PAD, dtype=np.uint8)
    buf[: len(data)] = np.frombuffer(data, dtype=np.uint8)
    return buf, len(data)


def records_to_plan(rec: BlockRecords, n: int) -> list[Sequence]:
    """Host-side: per-window records -> sequence plan (for byte emission)."""
    emit = np.asarray(rec.emit)
    pos = np.asarray(rec.pos)
    length = np.asarray(rec.length)
    offset = np.asarray(rec.offset)
    plan: list[Sequence] = []
    anchor = 0
    for w in np.nonzero(emit)[0]:
        plan.append(Sequence(anchor, int(pos[w]) - anchor, int(length[w]), int(offset[w])))
        anchor = int(pos[w]) + int(length[w])
    plan.append(Sequence(anchor, n - anchor))
    return plan


def compress_bytes(
    data: bytes,
    hash_bits: int = DEFAULT_HASH_BITS,
    max_match: int = DEFAULT_MAX_MATCH,
    use_pallas: bool = False,
    scan_impl: str = "sequential",
) -> list[bytes]:
    """Deprecated: use :class:`repro.core.engine.LZ4Engine`.

    Thin compatibility wrapper over the batched engine; still returns the
    historical list-of-raw-LZ4-blocks shape (no frame, no passthrough).
    """
    import warnings

    from .engine import LZ4Engine

    warnings.warn(
        "compress_bytes is deprecated; use LZ4Engine.compress (framed) or "
        "LZ4Engine.compress_to_blocks", DeprecationWarning, stacklevel=2,
    )
    eng = LZ4Engine(
        hash_bits=hash_bits, max_match=max_match,
        use_pallas=use_pallas, scan_impl=scan_impl,
    )
    return eng.compress_to_blocks(data)
