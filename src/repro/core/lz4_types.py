"""LZ4 block-format constants and the compression-plan data model.

The LZ4 block format (https://github.com/lz4/lz4/blob/dev/doc/lz4_Block_format.md):

  sequence := token | [lit-len ext bytes] | literals | offset(2B LE) | [match-len ext bytes]

  token high nibble = literal length (15 => extension bytes follow, each 255 until < 255)
  token low  nibble = match length - 4 (15 => extension bytes)

End-of-block rules used by the official compressor (and enforced here):
  * the last sequence is literals-only (no offset/matchlen fields),
  * the last 5 bytes are always literals (a match must end <= len-5),
  * a match must NOT start within the last 12 bytes (MF_LIMIT).
"""
from __future__ import annotations

import dataclasses

MIN_MATCH = 4                 # minimum encodable match length
MF_LIMIT = 12                 # no match may start within the last MF_LIMIT bytes
LAST_LITERALS = 5             # a match must end at least LAST_LITERALS before block end
MAX_OFFSET = 65535            # 16-bit offset field
HASH_PRIME = 2654435761       # Fibonacci hashing constant (paper Section II-B)
MAX_BLOCK = 65536             # LZ4 window / paper's input-buffer size (64 KB)

# Paper's hardware parameters (Section III/IV).
DEFAULT_PWS = 8               # parallelization window size in bytes
DEFAULT_MAX_MATCH = 36        # paper's chosen maximum match length limit
DEFAULT_HASH_BITS = 8         # 256 entries, as in [9][10] and the paper's architecture


@dataclasses.dataclass(frozen=True)
class Sequence:
    """One LZ4 sequence: `lit_len` literals starting at `lit_start`, then a match.

    ``match_len == 0`` marks the final literals-only sequence.
    """

    lit_start: int
    lit_len: int
    match_len: int = 0
    offset: int = 0

    def __post_init__(self):
        if self.match_len:
            if self.match_len < MIN_MATCH:
                raise ValueError(f"match_len {self.match_len} < {MIN_MATCH}")
            if not (1 <= self.offset <= MAX_OFFSET):
                raise ValueError(f"offset {self.offset} out of range")


def pad_pow2_count(count: int, cap: int) -> int:
    """Micro-batch row count for `count` items: the full `cap` when the
    batch is full, else the next power of two — so the number of compiled
    batch shapes stays bounded by log2(cap) + 1.  Shared by the compress
    and decode engines so their compile-shape bucketing cannot diverge."""
    if count >= cap:
        return cap
    return min(cap, 1 << (count - 1).bit_length()) if count > 1 else 1


def lit_ext_bytes(lit_len: int) -> int:
    """Number of literal-length extension bytes."""
    if lit_len < 15:
        return 0
    return 1 + (lit_len - 15) // 255


def match_ext_bytes(match_len: int) -> int:
    """Number of match-length extension bytes (match_len is the full length >= 4)."""
    m = match_len - MIN_MATCH
    if m < 15:
        return 0
    return 1 + (m - 15) // 255


def sequence_size(seq: Sequence) -> int:
    """Exact encoded size of one sequence in bytes."""
    size = 1 + lit_ext_bytes(seq.lit_len) + seq.lit_len
    if seq.match_len:
        size += 2 + match_ext_bytes(seq.match_len)
    return size


def plan_size(sequences: list[Sequence]) -> int:
    """Exact compressed-block size for a sequence plan."""
    return sum(sequence_size(s) for s in sequences)


def plan_coverage(sequences: list[Sequence]) -> int:
    """Total input bytes covered by a plan (must equal block length)."""
    return sum(s.lit_len + s.match_len for s in sequences)
