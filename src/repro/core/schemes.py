"""Numpy golden models of the paper's windowed hardware compressor.

Hardware semantics modeled (paper Sections II-B, III, IV):

* The block is processed in parallelization windows of PWS bytes, one window
  per clock cycle.
* Every cycle, ALL PWS positions are hashed and written into the hash table
  (LVT multi-port, last writer in window order wins).  Reads performed in the
  same cycle see the table state from *previous* cycles only (multi-port reads
  happen before the write phase).  Consequently the candidate for position p is

      cand(p) = max{ q : hash(q) == hash(p), window(q) < window(p) }

  which depends only on the byte stream — never on match decisions — and is
  precomputed vectorized here (and with a parallel sort in the JAX engine).
* The table stores the candidate's 4-byte string next to its pointer, so match
  validation is a word compare (no second buffer read).
* Single-match scheme (paper III-A): each window emits at most the EARLIEST
  valid match at a position not yet covered by a previous match (free pointer);
  the search always resumes at the next window boundary.
* Bounded extension (paper III-B): match length capped at `max_match`
  (None = unbounded, for the Table I row that isolates the single-match effect).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .lz4_types import (
    DEFAULT_MAX_MATCH,
    DEFAULT_PWS,
    LAST_LITERALS,
    MAX_BLOCK,
    MF_LIMIT,
    MIN_MATCH,
    Sequence,
)
from .reference import fib_hash, le32_words, match_length


@dataclasses.dataclass(frozen=True)
class WindowedResult:
    sequences: list[Sequence]
    # Per-window records, for the cycle model and for JAX-engine equality tests:
    emit: np.ndarray       # bool (W,) — window emitted a match
    pos: np.ndarray        # int  (W,) — match start position (or -1)
    length: np.ndarray     # int  (W,) — match length (or 0)
    offset: np.ndarray     # int  (W,) — match offset (or 0)


def window_candidates(hashes: np.ndarray, pws: int) -> np.ndarray:
    """cand(p) = max{q : hash(q)==hash(p), q//pws < p//pws}, else -1. Vectorized."""
    n = len(hashes)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    win = np.arange(n, dtype=np.int64) // pws
    order = np.lexsort((np.arange(n), hashes))  # by hash, then position
    h_s = hashes[order]
    w_s = win[order]
    p_s = order
    # Group = (hash, window) run.  The candidate for every element of a group is
    # the position just before the group head, provided it belongs to the same
    # hash run (then it automatically has a strictly smaller window index).
    head = np.ones(n, dtype=bool)
    head[1:] = (h_s[1:] != h_s[:-1]) | (w_s[1:] != w_s[:-1])
    head_idx = np.nonzero(head)[0]
    group_id = np.cumsum(head) - 1
    head_cand = np.full(len(head_idx), -1, dtype=np.int64)
    valid_head = head_idx > 0
    hi = head_idx[valid_head]
    same_hash = h_s[hi - 1] == h_s[hi]
    head_cand[valid_head] = np.where(same_hash, p_s[hi - 1], -1)
    cand_s = head_cand[group_id]
    out = np.empty(n, dtype=np.int64)
    out[order] = cand_s
    return out


def compress_windowed(
    data: bytes | np.ndarray,
    hash_bits: int = 12,
    pws: int = DEFAULT_PWS,
    max_match: int | None = DEFAULT_MAX_MATCH,
) -> WindowedResult:
    """The paper's single-match-per-window compressor (golden numpy model).

    max_match=None  -> Table I "only a single match" scheme (S1 alone)
    max_match=L     -> combined scheme (S1 + S2), paper default L=36
    """
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if not isinstance(data, np.ndarray) else data
    n = len(buf)
    if n > MAX_BLOCK:
        raise ValueError(f"block too large: {n} > {MAX_BLOCK}")
    n_windows = (n + pws - 1) // pws
    emit = np.zeros(n_windows, dtype=bool)
    pos = np.full(n_windows, -1, dtype=np.int64)
    length = np.zeros(n_windows, dtype=np.int64)
    offset = np.zeros(n_windows, dtype=np.int64)
    if n == 0:
        return WindowedResult([Sequence(0, 0)], emit, pos, length, offset)

    words = le32_words(buf)
    hashes = fib_hash(words, hash_bits)
    cand = window_candidates(hashes, pws)
    # Positions where a 4-byte match exists and a match may legally start:
    nw = len(words)
    valid4 = np.zeros(n, dtype=bool)
    has_cand = cand >= 0
    idx = np.nonzero(has_cand)[0]
    valid4[idx] = words[idx] == words[cand[idx]]
    limit_ip = n - MF_LIMIT
    valid4[max(0, limit_ip + 1):] = False

    fp = 0
    for w in range(n_windows):
        ws = w * pws
        we = min(ws + pws, n)
        start = max(ws, fp)
        if start >= we:
            continue
        hits = np.nonzero(valid4[start:we])[0]
        if len(hits) == 0:
            continue
        p = start + int(hits[0])
        q = int(cand[p])
        cap = n - LAST_LITERALS - p
        if max_match is not None:
            cap = min(cap, max_match)
        if cap < MIN_MATCH:
            continue
        mlen = MIN_MATCH + match_length(buf, p + MIN_MATCH, q + MIN_MATCH, cap - MIN_MATCH)
        emit[w] = True
        pos[w] = p
        length[w] = mlen
        offset[w] = p - q
        fp = p + mlen

    sequences = plan_from_matches(n, emit, pos, length, offset)
    return WindowedResult(sequences, emit, pos, length, offset)


def plan_from_matches(
    n: int,
    emit: np.ndarray,
    pos: np.ndarray,
    length: np.ndarray,
    offset: np.ndarray,
) -> list[Sequence]:
    """Build the sequence plan (literal runs between matches) from match records."""
    sequences: list[Sequence] = []
    anchor = 0
    for w in np.nonzero(emit)[0]:
        p, l, o = int(pos[w]), int(length[w]), int(offset[w])
        sequences.append(Sequence(anchor, p - anchor, l, o))
        anchor = p + l
    sequences.append(Sequence(anchor, n - anchor))
    return sequences


# ---------------------------------------------------------------------------
# Multi-match windowed model (Beneš [10]-style), used by the cycle model to
# reproduce the parallelism-loss analysis in paper Section III-A.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiMatchResult:
    sequences: list[Sequence]
    matches_per_window: np.ndarray   # int (W,)
    extension_reads: np.ndarray      # int (W,) — extra candidate reads (feedback loop trips)


def compress_windowed_multi(
    data: bytes | np.ndarray,
    hash_bits: int = 12,
    pws: int = DEFAULT_PWS,
) -> MultiMatchResult:
    """Windowed compressor that recovers ALL non-overlapping matches (FIFO scheme).

    Same LVT table semantics as compress_windowed, but within a window the
    search continues after each match (this is what costs the extra cycles).
    Extension is unbounded; each additional PWS-byte comparison beyond the
    first is counted as one feedback-loop trip.
    """
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if not isinstance(data, np.ndarray) else data
    n = len(buf)
    if n > MAX_BLOCK:
        raise ValueError(f"block too large: {n} > {MAX_BLOCK}")
    n_windows = (n + pws - 1) // pws
    matches_per_window = np.zeros(n_windows, dtype=np.int64)
    extension_reads = np.zeros(n_windows, dtype=np.int64)
    if n == 0:
        return MultiMatchResult([Sequence(0, 0)], matches_per_window, extension_reads)

    words = le32_words(buf)
    hashes = fib_hash(words, hash_bits)
    cand = window_candidates(hashes, pws)
    valid4 = np.zeros(n, dtype=bool)
    has_cand = cand >= 0
    idx = np.nonzero(has_cand)[0]
    valid4[idx] = words[idx] == words[cand[idx]]
    limit_ip = n - MF_LIMIT
    valid4[max(0, limit_ip + 1):] = False

    sequences: list[Sequence] = []
    anchor = 0
    fp = 0
    for w in range(n_windows):
        ws = w * pws
        we = min(ws + pws, n)
        p = max(ws, fp)
        while p < we:
            if not valid4[p]:
                p += 1
                continue
            q = int(cand[p])
            cap = n - LAST_LITERALS - p
            if cap < MIN_MATCH:
                break
            mlen = MIN_MATCH + match_length(buf, p + MIN_MATCH, q + MIN_MATCH, cap - MIN_MATCH)
            sequences.append(Sequence(anchor, p - anchor, mlen, p - q))
            matches_per_window[w] += 1
            # Feedback-loop trips: ceil((mlen - MIN_MATCH) / pws) candidate reads.
            extension_reads[w] += -(-(mlen - MIN_MATCH) // pws)
            anchor = p + mlen
            fp = p + mlen
            p = p + mlen
        fp = max(fp, we)

    sequences.append(Sequence(anchor, n - anchor))
    return MultiMatchResult(sequences, matches_per_window, extension_reads)
