"""Batched serving engine: prefill + decode loop with a request scheduler and
LZ4 KV-cache offload for paused sessions.

Static-batch design (TPU-friendly shapes): requests are grouped into fixed
batches; prompts are right-aligned/padded to the batch max, decode proceeds
greedily until max_new_tokens.  Paused sessions' KV caches can be offloaded
through the LZ4 engine (serialize -> compress -> host RAM/disk) and restored
bit-exactly — the paper's throughput-optimized compressor sits on exactly
this path in a production fleet.
"""
from __future__ import annotations

import dataclasses

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.decode_engine import FrameReader, default_decode_engine
from repro.core.engine import default_engine
from repro.core.frame import block_crc, encode_frame
from repro.models import lm
from repro.resilience.errors import FrameError


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list | None = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4, cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.queue: list[Request] = []
        self._decode = jax.jit(lm.decode_step, static_argnums=4)
        self._prefill = jax.jit(lm.prefill, static_argnums=(2, 3))

    def add_request(self, req: Request):
        self.queue.append(req)

    def _run_batch(self, reqs: list[Request]) -> None:
        B = len(reqs)
        max_p = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, max_p), np.int32)
        for i, r in enumerate(reqs):  # right-align so last token is real
            toks[i, max_p - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (B, self.cfg.enc_seq, self.cfg.d_model), jnp.dtype(self.cfg.compute_dtype)
            )
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (B, self.cfg.vision_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )
        with obs.span("serving.prefill", batch=B, max_prompt=max_p):
            cache, logits = self._prefill(self.params, batch, self.cfg, self.cache_len)
        outs = [[] for _ in reqs]
        steps = max(r.max_new_tokens for r in reqs)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        with obs.span("serving.decode_loop", batch=B, steps=steps):
            for _ in range(steps):
                for i in range(B):
                    outs[i].append(int(tok[i]))
                logits, cache = self._decode(self.params, cache, tok, cache["pos"], self.cfg)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = o[: r.max_new_tokens]

    def run(self) -> list[Request]:
        done = []
        while self.queue:
            batch = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            self._run_batch(batch)
            done.extend(batch)
        return done


# ---------------------------------------------------------------------------
# KV-cache offload through the LZ4 engine
# ---------------------------------------------------------------------------

def offload_cache(cache) -> tuple[list, dict]:
    """Serialize + LZ4-compress a cache pytree. Returns (blobs, stats).

    Each leaf becomes one self-describing frame: the engine batches all of
    the leaf's 64 KB blocks into micro-batched dispatches, and uncompressible
    blocks ride the frame's raw-passthrough flag — no out-of-band `lz4`
    markers or per-block length lists needed.
    """
    t0 = time.perf_counter()
    with obs.span("serving.offload"):
        leaves, treedef = jax.tree.flatten(cache)
        blobs = []
        raw_total = comp_total = 0
        for leaf in leaves:
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            if len(raw) >= 1024:
                frame = default_engine().compress(raw)
            elif raw:
                # Tiny leaf: a raw single-block frame, no kernel dispatch.
                frame = encode_frame([raw], [len(raw)], [True], checksums=[block_crc(raw)])
            else:
                frame = encode_frame([], [], [], checksums=[])
            blobs.append({"shape": arr.shape, "dtype": str(arr.dtype), "frame": frame})
            raw_total += len(raw)
            comp_total += len(frame)
    stats = {"raw": raw_total, "compressed": comp_total,
             "ratio": raw_total / max(comp_total, 1)}
    if obs.is_enabled():
        obs.counter("serving.offloads", "cache offloads").inc()
        obs.counter("serving.offload_bytes_raw",
                    "serialized cache bytes in").inc(raw_total)
        obs.counter("serving.offload_bytes_compressed",
                    "frame bytes out").inc(comp_total)
        obs.histogram("serving.offload_seconds",
                      help="offload_cache latency").observe(
            time.perf_counter() - t0)
        obs.histogram("serving.offload_ratio", obs.DEFAULT_RATIO_BUCKETS,
                      "whole-cache compression ratio").observe(stats["ratio"])
    return [treedef, blobs], stats


def _device_view(u8, dtype: np.dtype, shape):
    """Reinterpret a device uint8 array as `dtype` and reshape — the
    device-side twin of ``np.frombuffer(...).reshape(...)`` (bitcast, no
    transfer; byte order is the host's little-endian layout either way)."""
    dt = np.dtype(dtype)
    if dt.itemsize > 1:
        u8 = u8.reshape(-1, dt.itemsize)
    return jax.lax.bitcast_convert_type(u8, dt).reshape(shape)


def restore_cache(obj, decode_engine=None, to_device: bool = False,
                  verify: bool = True, on_error: str = "raise",
                  report: dict | None = None):
    """Full restore: every leaf frame through the parallel decode engine.

    ``on_error="salvage"``: a leaf frame that fails strict decode falls
    back to the salvage pass (`repro.resilience.salvage`) — every
    undamaged block is recovered, frame-v6 parity reconstructs what it
    can prove, and lost spans are zero-filled so the restored tree keeps
    its shapes.  Damage is recorded in ``report`` (leaf index ->
    `SalvageReport`) and the ``resilience.*`` obs counters — never
    silently.  The default ``"raise"`` keeps the strict contract.

    ``to_device=True`` routes each frame through the decode engine's
    device executor (`decode_to_device`): blocks are decompressed inside
    the jit graph and the restored leaves are assembled as device arrays.
    The restore is fully accelerator-to-accelerator either way — with the
    default ``verify=True`` each block's CRC32 is computed in-graph
    (`kernels.ops.crc32_bytes`) and only the 4-byte checksum is synced for
    comparison, so zero plaintext bytes cross to the host
    (`DecodeStats.host_bytes` 0); ``verify=False`` skips even that scalar
    sync and defers integrity to the caller.  An engine configured with
    ``plan_on_device=True`` keeps even token-stream PLANNING on device
    (the speculative planner, kernels/plan_speculative.py) — the restore
    then has no per-byte host stage at all.
    """
    if on_error not in ("raise", "salvage"):
        raise ValueError('on_error must be "raise" or "salvage"')
    t0 = time.perf_counter()
    treedef, blobs = obj
    eng = decode_engine or default_decode_engine()
    leaves = []
    with obs.span("serving.restore", leaves=len(blobs), to_device=to_device):
        for i, b in enumerate(blobs):
            if to_device:
                try:
                    raw = eng.decode_to_device(b["frame"], verify=verify)
                except FrameError:
                    if on_error != "salvage":
                        raise
                    # Host salvage, then upload: correctness first — the
                    # damaged-frame path is the rare one.
                    rep = eng.salvage(b["frame"])
                    if report is not None:
                        report[i] = rep
                    raw = jnp.asarray(np.frombuffer(rep.data, np.uint8))
                leaves.append(_device_view(raw, np.dtype(b["dtype"]), b["shape"]))
            else:
                try:
                    raw = eng.decode(b["frame"]) if on_error != "salvage" \
                        else eng._decode_strict(b["frame"])
                except FrameError:
                    if on_error != "salvage":
                        raise
                    rep = eng.salvage(b["frame"])
                    if report is not None:
                        report[i] = rep
                    raw = rep.data
                leaves.append(jnp.asarray(
                    np.frombuffer(raw, np.dtype(b["dtype"])).reshape(b["shape"])))
        tree = jax.tree.unflatten(treedef, leaves)
    if obs.is_enabled():
        obs.counter("serving.restores", "cache restores").inc()
        obs.histogram("serving.restore_seconds",
                      help="restore_cache latency").observe(
            time.perf_counter() - t0)
    return tree


class OffloadedCacheReader:
    """Random access into an offloaded cache without a full restore.

    A paused session's cache can be multi-GB; resuming one request, or
    inspecting one layer's KV slice, should not pay a full-tree decompress.
    Each leaf frame gets a lazy `FrameReader`, so a read decodes only the
    64 KB blocks covering the requested element range (the frame block
    table is the seek index) — single-block reads stay single-block.

    ``to_device=True`` makes every read return DEVICE arrays: the covering
    blocks are decompressed inside the jit graph (the decode engine's
    device executor) and sliced/reshaped on the accelerator — the
    accelerator-to-accelerator path a production serving fleet wants
    between offload tiers, with zero plaintext bytes crossing to the host
    (including planning, when the engine speculates in-graph via
    ``plan_on_device=True``).  The default ``verify=True`` keeps that
    property: each block's CRC32 runs in-graph and only the 4-byte
    checksum is synced for comparison; ``verify=False`` defers integrity
    to the caller and skips the sync.

    >>> rdr = OffloadedCacheReader(blob)
    >>> rdr.read_leaf(3, start=128, count=64)   # 64 elements, ~1 block decoded
    >>> OffloadedCacheReader(blob, to_device=True).read_leaf(3)  # jax.Array
    """

    def __init__(self, obj, decode_engine=None, to_device: bool = False,
                 verify: bool = True, on_error: str = "raise"):
        if on_error not in ("raise", "salvage"):
            raise ValueError('on_error must be "raise" or "salvage"')
        self._treedef, self._blobs = obj
        self._engine = decode_engine or default_decode_engine()
        self._to_device = to_device
        self._verify = verify
        # on_error="salvage": leaf readers are built with the tolerant table
        # parse (damaged leaves still expose their readable blocks) and
        # `salvage_leaf` recovers a whole leaf with holes accounted for.
        self.on_error = on_error
        self._readers: list[FrameReader | None] = [None] * len(self._blobs)

    def __len__(self) -> int:
        return len(self._blobs)

    def leaf_meta(self, i: int) -> tuple[tuple, np.dtype]:
        b = self._blobs[i]
        return tuple(b["shape"]), np.dtype(b["dtype"])

    def _reader(self, i: int) -> FrameReader:
        if self._readers[i] is None:
            self._readers[i] = FrameReader(self._blobs[i]["frame"],
                                           engine=self._engine,
                                           on_error=self.on_error)
        return self._readers[i]

    def salvage_leaf(self, i: int):
        """Salvage pass over leaf i's frame: decode every undamaged block,
        reconstruct from v6 parity where provable, zero-fill the rest.
        Returns the `SalvageReport` (repro/resilience/salvage.py) — its
        ``data`` is the leaf's full-length serialized buffer."""
        return self._engine.salvage(self._blobs[i]["frame"])

    def read_leaf_bytes(self, i: int, start: int = 0,
                        length: int | None = None) -> bytes:
        """Byte range of leaf i's serialized buffer (seek-indexed decode)."""
        reader = self._reader(i)
        if length is None:
            length = reader.usize - start
        return reader.read_range(start, length)

    def read_leaf(self, i: int, start: int = 0, count: int | None = None):
        """Flat element slice [start, start+count) of leaf i.

        Returns np.ndarray, or a device-resident jax.Array when the reader
        was built with ``to_device=True`` (the covering blocks decode
        in-graph and only device memory holds the plaintext slice).
        """
        shape, dtype = self.leaf_meta(i)
        total = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if count is None:
            count = total - start
        if start < 0 or count < 0 or start + count > total:
            raise ValueError(f"slice [{start}, {start + count}) outside leaf of {total}")
        t0 = time.perf_counter()
        with obs.span("serving.read_leaf", leaf=i, count=count,
                      to_device=self._to_device):
            if self._to_device:
                raw = self._reader(i).read_range_device(
                    start * dtype.itemsize, count * dtype.itemsize,
                    verify=self._verify)
                out = _device_view(raw, dtype, (count,))
            else:
                raw = self.read_leaf_bytes(i, start * dtype.itemsize,
                                           count * dtype.itemsize)
                out = np.frombuffer(raw, dtype)
        if obs.is_enabled():
            obs.histogram("serving.read_leaf_seconds",
                          help="partial-restore (resume) read latency"
                          ).observe(time.perf_counter() - t0)
        return out

    def restore(self, report: dict | None = None):
        """Full pytree restore (equivalent to `restore_cache`)."""
        return restore_cache([self._treedef, self._blobs], self._engine,
                             to_device=self._to_device, verify=self._verify,
                             on_error=self.on_error, report=report)
