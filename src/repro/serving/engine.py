"""Batched serving engine: prefill + decode loop with a request scheduler and
LZ4 KV-cache offload for paused sessions.

Static-batch design (TPU-friendly shapes): requests are grouped into fixed
batches; prompts are right-aligned/padded to the batch max, decode proceeds
greedily until max_new_tokens.  Paused sessions' KV caches can be offloaded
through the LZ4 engine (serialize -> compress -> host RAM/disk) and restored
bit-exactly — the paper's throughput-optimized compressor sits on exactly
this path in a production fleet.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import default_engine
from repro.core.frame import decode_frame, encode_frame
from repro.models import lm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list | None = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4, cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.queue: list[Request] = []
        self._decode = jax.jit(lm.decode_step, static_argnums=4)
        self._prefill = jax.jit(lm.prefill, static_argnums=(2, 3))

    def add_request(self, req: Request):
        self.queue.append(req)

    def _run_batch(self, reqs: list[Request]) -> None:
        B = len(reqs)
        max_p = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, max_p), np.int32)
        for i, r in enumerate(reqs):  # right-align so last token is real
            toks[i, max_p - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (B, self.cfg.enc_seq, self.cfg.d_model), jnp.dtype(self.cfg.compute_dtype)
            )
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (B, self.cfg.vision_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )
        cache, logits = self._prefill(self.params, batch, self.cfg, self.cache_len)
        outs = [[] for _ in reqs]
        steps = max(r.max_new_tokens for r in reqs)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(steps):
            for i in range(B):
                outs[i].append(int(tok[i]))
            logits, cache = self._decode(self.params, cache, tok, cache["pos"], self.cfg)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r, o in zip(reqs, outs):
            r.output = o[: r.max_new_tokens]

    def run(self) -> list[Request]:
        done = []
        while self.queue:
            batch = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            self._run_batch(batch)
            done.extend(batch)
        return done


# ---------------------------------------------------------------------------
# KV-cache offload through the LZ4 engine
# ---------------------------------------------------------------------------

def offload_cache(cache) -> tuple[list, dict]:
    """Serialize + LZ4-compress a cache pytree. Returns (blobs, stats).

    Each leaf becomes one self-describing frame: the engine batches all of
    the leaf's 64 KB blocks into micro-batched dispatches, and uncompressible
    blocks ride the frame's raw-passthrough flag — no out-of-band `lz4`
    markers or per-block length lists needed.
    """
    leaves, treedef = jax.tree.flatten(cache)
    blobs = []
    raw_total = comp_total = 0
    for leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        if len(raw) >= 1024:
            frame = default_engine().compress(raw)
        elif raw:
            # Tiny leaf: a raw single-block frame, no kernel dispatch.
            frame = encode_frame([raw], [len(raw)], [True])
        else:
            frame = encode_frame([], [], [])
        blobs.append({"shape": arr.shape, "dtype": str(arr.dtype), "frame": frame})
        raw_total += len(raw)
        comp_total += len(frame)
    stats = {"raw": raw_total, "compressed": comp_total,
             "ratio": raw_total / max(comp_total, 1)}
    return [treedef, blobs], stats


def restore_cache(obj):
    treedef, blobs = obj
    leaves = []
    for b in blobs:
        raw = decode_frame(b["frame"])
        leaves.append(jnp.asarray(np.frombuffer(raw, np.dtype(b["dtype"])).reshape(b["shape"])))
    return jax.tree.unflatten(treedef, leaves)
