"""Opt-in GPipe-style pipeline parallelism over the "pod" axis.

The production posture for the assigned mesh keeps "pod" as an outer DP axis
(FSDP+TP fit the largest assigned model with headroom, and pod=2 pipelines
poorly: bubble = (S-1)/(T+S-1)).  This module provides the PP building block
for meshes where it *is* the right call (deep models on many pods):
microbatches flow stage -> stage via jax.lax.ppermute inside shard_map —
the jax-native mapping of the 1F1B/GPipe communication pattern.

Semantics: `pipeline_apply(stage_fn, stage_params, x)` computes

    y = stage_fn(p[S-1], stage_fn(p[S-2], ... stage_fn(p[0], x)))

with the S stages resident on S pods, T microbatches in flight, verified
token-exact against the sequential composition in tests/test_pipeline.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import get_mesh, shard_map_compat as _shard_map_compat


def pipeline_apply(stage_fn, stage_params, x, *, axis: str = "pod", n_micro: int | None = None):
    """Run a pipelined stack of stages.

    stage_fn     : (params_leaf_tree, (mb, ...)) -> (mb, ...)
    stage_params : pytree with leading axis = n_stages on every leaf
    x            : (batch, ...) global input (batch % n_micro == 0)
    """
    mesh = get_mesh()
    S = mesh.shape[axis]
    B = x.shape[0]
    T = n_micro or S  # default: as many microbatches as stages
    assert B % T == 0, (B, T)
    mb = B // T
    xm = x.reshape(T, mb, *x.shape[1:])

    def local(params_local, xm_local):
        # params_local leaves: (1, ...) — this stage's slice
        p_mine = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        steps = T + S - 1
        fwd_perm = [(i, i + 1) for i in range(S - 1)]

        def body(carry, t):
            recv, outbuf = carry
            # stage 0 ingests microbatch t (zeros once the stream is done)
            feed = jnp.where(
                t < T,
                jax.lax.dynamic_index_in_dim(xm_local, jnp.minimum(t, T - 1), 0,
                                             keepdims=False),
                jnp.zeros_like(recv),
            )
            inp = jnp.where(stage == 0, feed, recv)
            out = stage_fn(p_mine, inp)
            # last stage collects microbatch (t - (S-1)) once warm
            slot = jnp.clip(t - (S - 1), 0, T - 1)
            take = (stage == S - 1) & (t >= S - 1)
            outbuf = jax.lax.dynamic_update_index_in_dim(
                outbuf,
                jnp.where(take, out, jax.lax.dynamic_index_in_dim(outbuf, slot, 0, False)),
                slot, 0,
            )
            recv = jax.lax.ppermute(out, axis, fwd_perm)
            return (recv, outbuf), None

        recv0 = jnp.zeros_like(xm_local[0])
        outbuf0 = jnp.zeros_like(xm_local)
        (_, outbuf), _ = jax.lax.scan(body, (recv0, outbuf0), jnp.arange(steps))
        # only the last stage holds real outputs; broadcast them to all pods
        outbuf = jax.lax.psum(
            jnp.where(stage == S - 1, outbuf, jnp.zeros_like(outbuf)), axis
        )
        return outbuf

    out = _shard_map_compat()(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, xm)
    return out.reshape(B, *x.shape[1:])


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble: idle fraction of the pipeline schedule."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
