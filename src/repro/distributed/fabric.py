"""Sharded multi-chip compression fabric: `shard_map` compress/decode.

The paper's throughput story — many parallelization windows compressing
independently — scales past one chip only if the BLOCK STACK itself is
sharded.  This module is that refactor: the 64 KB block stack is partitioned
into contiguous per-shard slices over the mesh axes defined in
`repro/distributed/sharding.py`, each mesh shard runs the existing fused/auto
datapath (`compress_block_bytes` / `kernels.ops.decode_gather`) on its slice
inside ONE `shard_map`-wrapped vmapped jit dispatch, and the per-shard
outputs merge into a **frame v4** container — a shard-aware block table
(`src/repro/core/frame.py`) that stays seekable across shard boundaries.

Partition-compress-merge is the container shape parallel producers want
(Rapidgzip, arXiv 2308.08955; Noel et al. 2023 survey exactly this
decomposition): blocks remain independent and in global content order, so
`FrameReader.read_range` / `read_range_device` work on v4 frames unchanged,
and any single shard's run is byte-identical to a single-device engine run
on the same slice (the fabric's core invariant, asserted by
`tests/test_distributed.py` and `benchmarks/sharded_fabric.py`).

Two execution paths, bit-identical by construction:

  * **mesh path** (`mesh` with >1 shard): one global
    ``(S*r, MAX_BLOCK+_PAD)`` stack per step, `shard_map` splits it along
    the shard axes, every shard compresses its ``r`` rows concurrently,
    and the two-step sliced drain fetches exactly the compressed payload
    bytes.  Decode mirrors it: host planning (`plan_block_fast` ->
    `to_device_plan`) stacks fixed-shape `DevicePlan`s per shard and one
    `shard_map`(vmap(`decode_gather`)) dispatch resolves every block.
  * **host path** (no mesh, or a 1-shard mesh): each shard's slice runs
    through a plain single-device `LZ4Engine` worker sequentially — the
    ORACLE the mesh path is pinned against, and what keeps the v4 writer
    (and its differential tests) runnable on a single-device container.

Spans (`repro.obs`): the fabric reuses the engine's ``compress.pad`` /
``compress.dispatch`` / ``compress.wait`` / ``compress.drain`` stage names
(with ``shards=`` attributes) and adds ``compress.shard`` (one per shard on
the host path) and ``compress.merge`` — the per-stage table from
`tools/trace_report.py` shows the merge cost directly.  Counters:
``fabric.dispatches``, ``fabric.merged_blocks``, ``fabric.fallback_blocks``.

See docs/architecture.md (fabric section) and docs/tuning.md (mesh-shape
guidance) for when sharding pays.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.decode_plan import execute_plan
from repro.core.decoder import LZ4FormatError
from repro.core.frame import FrameFormatError, block_crc, check_block, encode_frame, frame_info
from repro.core.jax_compressor import _PAD, compress_block_bytes
from repro.core.lz4_types import MAX_BLOCK, pad_pow2_count

from .sharding import shard_map_compat

__all__ = [
    "ShardSlice",
    "partition_blocks",
    "mesh_shard_count",
    "compress_sharded",
    "decode_items_sharded",
    "shard_subframe",
]


@dataclasses.dataclass(frozen=True)
class ShardSlice:
    """Contiguous run of global block indices owned by one shard."""

    shard: int
    start: int
    stop: int

    @property
    def count(self) -> int:
        return self.stop - self.start


def partition_blocks(n_blocks: int, shards: int) -> list[ShardSlice]:
    """Balanced contiguous partition of ``n_blocks`` across ``shards``.

    The first ``n_blocks % shards`` shards take one extra block, so uneven
    stacks (blocks % shards != 0) differ by at most one block per shard and
    trailing shards may own zero blocks when blocks < shards.  Contiguity
    is what keeps the merged v4 frame in global content order.
    """
    if shards < 1:
        raise ValueError("shards must be >= 1")
    base, rem = divmod(n_blocks, shards)
    out, pos = [], 0
    for s in range(shards):
        c = base + (1 if s < rem else 0)
        out.append(ShardSlice(s, pos, pos + c))
        pos += c
    return out


def mesh_shard_count(mesh, shard_axes) -> int:
    """Total shard count = product of the mesh sizes of ``shard_axes``."""
    return int(np.prod([mesh.shape[a] for a in shard_axes], dtype=np.int64)) or 1


# ---------------------------------------------------------------------------
# Compress: shard_map over the block stack.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_compress_compiled(mesh, shard_axes, hash_bits, max_match, pws,
                               use_pallas, scan_impl, candidate_impl):
    """jit(shard_map(vmap(compress_block_bytes))) cached per static config.

    The leading (block) dim of the stack is split over ``shard_axes``; each
    shard runs the plain vmapped single-block graph on its rows — no
    collectives anywhere, so the per-row bytes are identical to the
    single-device dispatch (the invariant the tests pin).
    """
    fn = functools.partial(
        compress_block_bytes,
        hash_bits=hash_bits, max_match=max_match, pws=pws,
        use_pallas=use_pallas, scan_impl=scan_impl,
        candidate_impl=candidate_impl,
    )
    spec = P(shard_axes)
    sm = shard_map_compat()(
        jax.vmap(fn), mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec),
        check_vma=False,
    )
    return jax.jit(sm)


def _fetch_payload(st, sp, out_dev, row: int, size: int) -> bytes:
    """Slice-fetch exactly ``size`` compressed bytes of one stacked row."""
    with sp("compress.drain", bytes=size):
        data = np.asarray(out_dev[row, :size]).tobytes()
    st.host_bytes += size
    return data


def _mesh_collect(engine, chunks, slices, st, sp):
    """Mesh path: per-shard lists of (chunk, n, size, payload_fn).

    One step processes up to ``micro_batch`` blocks PER SHARD: the global
    stack is ``(S*r, MAX_BLOCK+_PAD)`` with shard i owning rows
    ``[i*r, (i+1)*r)`` (``r`` power-of-two-padded so compiled shapes stay
    bounded; rows past a shard's slice carry n=0 and are never drained).
    Dispatch is double-buffered like the single-device engine: step k+1 is
    stacked and dispatched before the host syncs on step k's size vector.
    """
    per = [chunks[sl.start: sl.stop] for sl in slices]
    S = len(per)
    steps = max((len(p) for p in per), default=0)
    mb = engine.micro_batch
    fn = _sharded_compress_compiled(
        engine.mesh, tuple(engine.shard_axes), engine.hash_bits,
        engine.max_match, engine.pws, engine.use_pallas, engine.scan_impl,
        engine.candidate_impl,
    )
    out_lists: list[list] = [[] for _ in range(S)]

    def drain(meta, res):
        start, counts, r = meta
        out_dev, size_dev = res
        with sp("compress.wait", rows=sum(counts), shards=S):
            sizes = jax.device_get(size_dev)
        st.host_bytes += sizes.nbytes
        for i, cnt in enumerate(counts):
            for j in range(cnt):
                row = i * r + j
                chunk = per[i][start + j]
                size = int(sizes[row])
                out_lists[i].append((chunk, len(chunk), size,
                                     functools.partial(_fetch_payload, st, sp,
                                                       out_dev, row, size)))

    inflight = None
    for start in range(0, steps, mb):
        counts = [max(0, min(mb, len(p) - start)) for p in per]
        r = pad_pow2_count(max(counts), mb)
        with sp("compress.pad", blocks=sum(counts), shards=S):
            stack = np.zeros((S * r, MAX_BLOCK + _PAD), np.uint8)
            ns = np.zeros((S * r,), np.int32)
            for i, p in enumerate(per):
                for j in range(counts[i]):
                    c = p[start + j]
                    row = i * r + j
                    stack[row, : len(c)] = np.frombuffer(c, np.uint8)
                    ns[row] = len(c)
        st.dispatches += 1
        with sp("compress.dispatch", rows=sum(counts), shards=S,
                impl=engine.candidate_impl):
            res = fn(jnp.asarray(stack), jnp.asarray(ns))
        if inflight is not None:
            drain(*inflight)
        inflight = ((start, counts, r), res)
    if inflight is not None:
        drain(*inflight)
    return out_lists


def _host_collect(engine, chunks, slices, st, sp):
    """Host path: each shard's slice through a single-device worker engine.

    This IS the per-shard oracle — shard i's payload bytes are produced by
    exactly the dispatch a standalone `LZ4Engine` would run on the slice,
    so mesh-path equality checks reduce to comparing against this path.
    """
    worker = engine._shard_worker()
    out_lists: list[list] = [[] for _ in slices]
    for sl in slices:
        if sl.count == 0:
            continue
        piece = b"".join(chunks[sl.start: sl.stop])
        with sp("compress.shard", shard=sl.shard, blocks=sl.count):
            out_lists[sl.shard] = list(worker._payload_iter(piece, st))
    return out_lists


def compress_sharded(engine, data: bytes, st) -> bytes:
    """bytes -> frame v4, sharded across ``engine.shards`` producers.

    ``st`` is the engine call's `EngineStats` (the caller owns lifecycle).
    Blocks are partitioned contiguously (`partition_blocks`), compressed on
    the mesh path when ``engine.mesh`` spans >1 shard (host-worker path
    otherwise), and merged — raw-passthrough decisions, CRCs, and the v4
    shard column — under one ``compress.merge`` span.
    """
    ob = engine._obs_on()
    sp = obs.span_factory(ob)
    chunks = [data[i: i + MAX_BLOCK] for i in range(0, len(data), MAX_BLOCK)]
    S = engine.shards
    st.shards = S
    slices = partition_blocks(len(chunks), S)
    if engine.mesh is not None and S > 1:
        # Host path counts blocks/bytes_in inside the worker's
        # `_payload_iter`; the mesh path counts them here.
        st.blocks += len(chunks)
        st.bytes_in += len(data)
        per_shard = _mesh_collect(engine, chunks, slices, st, sp)
    else:
        per_shard = _host_collect(engine, chunks, slices, st, sp)
    ratio_hist = obs.registry().histogram(
        "engine.block_ratio", obs.DEFAULT_RATIO_BUCKETS,
        "per-block compression ratio usize/csize (raw blocks -> 1.0)",
    ) if ob else None
    payloads, usizes, raws, crcs, shard_ids = [], [], [], [], []
    with sp("compress.merge", blocks=len(chunks), shards=S):
        for sl, items in zip(slices, per_shard):
            for chunk, n, size, payload_fn in items:
                if size >= n:
                    payloads.append(chunk)
                    raws.append(True)
                    st.raw_blocks += 1
                    if ratio_hist is not None and n:
                        ratio_hist.observe(1.0)
                else:
                    payloads.append(payload_fn())
                    raws.append(False)
                    if ratio_hist is not None and size:
                        ratio_hist.observe(n / size)
                usizes.append(n)
                crcs.append(block_crc(chunk))
                shard_ids.append(sl.shard)
        pg = getattr(engine, "parity_group", None)
        frame = encode_frame(payloads, usizes, raws, checksums=crcs,
                             shards=shard_ids, shard_count=S,
                             content_crc=block_crc(data)
                             if (getattr(engine, "content_crc", False)
                                 or pg is not None)
                             else None,
                             parity_group=pg)
    if ob:
        r = obs.registry()
        r.counter("fabric.dispatches",
                  "sharded compress/decode jit dispatches").inc(st.dispatches)
        r.counter("fabric.merged_blocks",
                  "blocks merged into v4 frames").inc(len(chunks))
    st.bytes_out = len(frame)
    return frame


def shard_blocks_sharded(engine, data: bytes, st) -> list[bytes]:
    """Sharded twin of `LZ4Engine.compress_to_blocks` (raw LZ4 blocks, no
    framing, no raw-passthrough): every block's bytes via its shard's
    datapath, returned in global order."""
    sp = obs.span_factory(engine._obs_on())
    chunks = [data[i: i + MAX_BLOCK] for i in range(0, len(data), MAX_BLOCK)]
    st.shards = engine.shards
    slices = partition_blocks(len(chunks), engine.shards)
    if engine.mesh is not None and engine.shards > 1:
        st.blocks += len(chunks)
        st.bytes_in += len(data)
        per_shard = _mesh_collect(engine, chunks, slices, st, sp)
    else:
        per_shard = _host_collect(engine, chunks, slices, st, sp)
    out = []
    with sp("compress.merge", blocks=len(chunks), shards=engine.shards,
            framing=False):
        for items in per_shard:
            out.extend(payload_fn() for _, _, _, payload_fn in items)
    return out


# ---------------------------------------------------------------------------
# Decode: shard_map over stacked DevicePlans.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_decode_compiled(mesh, shard_axes, out_cap, rounds, use_pallas):
    """jit(shard_map(vmap(decode_gather))) cached per static config."""
    from repro.kernels.ops import decode_gather

    fn = functools.partial(decode_gather, out_cap=out_cap, rounds=rounds,
                           use_pallas=use_pallas)
    spec = P(shard_axes)
    sm = shard_map_compat()(
        jax.vmap(fn), mesh=mesh,
        in_specs=(spec,) * 9, out_specs=spec,
        check_vma=False,
    )
    return jax.jit(sm)


@functools.lru_cache(maxsize=None)
def _sharded_plan_decode_compiled(mesh, shard_axes, out_cap, max_lit,
                                  max_match, rounds, use_pallas):
    """jit(shard_map(vmap(plan_decode))) cached per static config — the
    speculative-planning twin of `_sharded_decode_compiled`: every shard
    parses, validates, and decodes its raw payload rows in one fused graph
    (no host token parse anywhere).  CRC verification stays on host here
    (the sharded frame path returns host bytes and runs `check_block`)."""
    from repro.kernels.ops import plan_decode

    fn = functools.partial(plan_decode, out_cap=out_cap, max_lit=max_lit,
                           max_match=max_match, rounds=rounds,
                           use_pallas=use_pallas, compute_crc=False)
    spec = P(shard_axes)
    sm = shard_map_compat()(
        jax.vmap(fn), mesh=mesh,
        in_specs=(spec,) * 3, out_specs=(spec, spec, spec),
        check_vma=False,
    )
    return jax.jit(sm)


def _round_bucket(rounds: int) -> int:
    if rounds <= 0:
        return 0
    b = 1
    while b < rounds:
        b <<= 1
    return b


def decode_items_sharded(engine, items, st) -> list:
    """Sharded device decode of independent blocks.

    ``items``: list of ``(index, payload, usize, crc, raw)`` in output
    order (``crc`` None skips the checksum, ``usize`` None caps at
    MAX_BLOCK).  Raw blocks short-circuit; blocks whose plans overflow
    `DevicePlanCaps` fall back to host execution (counted in
    ``st.fallback_blocks``); the rest are planned on host, partitioned
    contiguously across the mesh shards, and executed by
    `shard_map`(vmap(`decode_gather`)) dispatches — the read-side mirror of
    the compress fabric.  Returns the decoded bytes per item.

    Engines with ``plan_on_device=True`` route to the speculative path
    instead: raw payloads are stacked as-is and
    `shard_map`(vmap(`plan_decode`)) parses + validates + decodes them in
    one fused dispatch per step, with per-row status vectors checked at
    drain (`_decode_items_sharded_spec`).
    """
    if getattr(engine, "plan_on_device", False):
        return _decode_items_sharded_spec(engine, items, st)
    ob = engine._obs_on()
    sp = obs.span_factory(ob)
    out: list = [None] * len(items)
    jobs = []  # (slot, index, usize, crc, payload, dplan)
    for slot, (i, payload, usize, crc, raw) in enumerate(items):
        if raw:
            with sp("decode.verify", block=i, raw=True):
                check_block(i, usize if usize is not None else len(payload),
                            crc, payload)
            out[slot] = payload
            continue
        try:
            plan, dplan = engine._plan_for_device(
                payload, usize if usize is not None else MAX_BLOCK)
        except FrameFormatError:
            raise
        except LZ4FormatError as e:
            raise FrameFormatError(f"block {i}: {e}") from e
        if usize is not None and plan.usize != usize:
            raise FrameFormatError(
                f"block {i}: decoded {plan.usize} bytes, table says {usize}"
            )
        if dplan is None:
            st.fallback_blocks += 1
            with sp("decode.execute", block=i, fallback=True):
                data = execute_plan(payload, plan).tobytes()
            with sp("decode.verify", block=i):
                check_block(i, plan.usize, crc, data)
            out[slot] = data
            continue
        jobs.append((slot, i, plan.usize, crc, payload, dplan))

    if not jobs:
        return out

    caps = engine.caps
    S = engine.shards
    slices = partition_blocks(len(jobs), S)
    per = [jobs[sl.start: sl.stop] for sl in slices]
    steps = max(len(p) for p in per)
    mb = engine.micro_batch

    def drain(meta, res):
        start, counts, r = meta
        for i, cnt in enumerate(counts):
            for j in range(cnt):
                slot, idx, usize, crc, _payload, _dp = per[i][start + j]
                row = res[i * r + j]
                with sp("decode.drain", bytes=usize):
                    data = np.asarray(row[:usize]).tobytes()
                st.host_bytes += usize
                with sp("decode.verify", block=idx):
                    check_block(idx, usize, crc, data)
                out[slot] = data

    inflight = None
    for start in range(0, steps, mb):
        counts = [max(0, min(mb, len(p) - start)) for p in per]
        r = pad_pow2_count(max(counts), mb)
        blk = np.zeros((S * r, caps.blk_cap), np.uint8)
        lit = [np.zeros((S * r, caps.max_lit), np.int32) for _ in range(3)]
        mat = [np.zeros((S * r, caps.max_match), np.int32) for _ in range(2)]
        scal = [np.zeros((S * r,), np.int32) for _ in range(3)]
        rounds = 0
        for i in range(S):
            for j in range(counts[i]):
                _slot, _idx, _usize, _crc, payload, dp = per[i][start + j]
                row = i * r + j
                blk[row, : len(payload)] = np.frombuffer(payload, np.uint8)
                lit[0][row], lit[1][row], lit[2][row] = (dp.lit_src, dp.lit_dst,
                                                         dp.lit_len)
                mat[0][row], mat[1][row] = dp.match_dst, dp.match_off
                scal[0][row], scal[1][row], scal[2][row] = (dp.n_lit,
                                                            dp.n_match,
                                                            dp.out_size)
                rounds = max(rounds, dp.n_waves)
        fn = _sharded_decode_compiled(engine.mesh, tuple(engine.shard_axes),
                                      caps.out_cap, _round_bucket(rounds),
                                      engine.use_pallas)
        st.dispatches += 1
        st.device_blocks += sum(counts)
        with sp("decode.execute", rows=sum(counts), shards=S,
                executor="sharded", rounds=rounds):
            res = fn(jnp.asarray(blk), *(jnp.asarray(a) for a in lit),
                     *(jnp.asarray(a) for a in mat),
                     *(jnp.asarray(a) for a in scal))
        if inflight is not None:
            drain(*inflight)
        inflight = ((start, counts, r), res)
    if inflight is not None:
        drain(*inflight)
    if ob:
        obs.registry().counter(
            "fabric.dispatches",
            "sharded compress/decode jit dispatches").inc(st.dispatches)
        obs.registry().counter(
            "fabric.fallback_blocks",
            "sharded-decode blocks executed on host "
            "(plan overflowed DevicePlanCaps)").inc(st.fallback_blocks)
    return out


def _spec_host_fallback_item(engine, i, payload, usize, crc, st, sp):
    """Host plan+execute for one sharded item the speculative path cannot
    keep on device (payload over `blk_cap` or caps overflow) — counted,
    size-checked against the table, and CRC-verified like the host-planner
    fallback."""
    from repro.core.decode_plan import plan_block_fast

    st.fallback_blocks += 1
    try:
        with sp("decode.plan", bytes_in=len(payload), executor="device",
                fallback=True):
            plan = plan_block_fast(
                payload, max_out=usize if usize is not None else MAX_BLOCK)
    except FrameFormatError:
        raise
    except LZ4FormatError as e:
        raise FrameFormatError(f"block {i}: {e}") from e
    if usize is not None and plan.usize != usize:
        raise FrameFormatError(
            f"block {i}: decoded {plan.usize} bytes, table says {usize}")
    with sp("decode.execute", block=i, fallback=True):
        data = execute_plan(payload, plan).tobytes()
    with sp("decode.verify", block=i):
        check_block(i, plan.usize, crc, data)
    return data


def _decode_items_sharded_spec(engine, items, st) -> list:
    """`decode_items_sharded` with speculative in-graph planning.

    No host token parse: raw compressed payloads are stacked into the
    ``(S*r, blk_cap + SPEC_PAD)`` global buffer with their lengths and
    size caps, and ONE `shard_map`(vmap(`plan_decode`)) dispatch per step
    parses candidate headers, selects chains, validates, lays out, and
    decodes every shard's rows.  The host consumes only each row's
    (SPEC_STATUS,) status vector at drain — parse errors raise the host
    planner's exact per-block message, size mismatches the ``table says``
    message, caps overflows take the counted host fallback (error parity
    with `LZ4DecodeEngine._decode_entries_specplan`).
    """
    from repro.core.decode_engine import _spec_err_message
    from repro.core.decode_plan import MAX_RESOLVE_ROUNDS
    from repro.kernels import ops as kops

    ob = engine._obs_on()
    sp = obs.span_factory(ob)
    out: list = [None] * len(items)
    jobs = []  # (slot, index, usize, crc, payload, max_out)
    for slot, (i, payload, usize, crc, raw) in enumerate(items):
        if raw:
            with sp("decode.verify", block=i, raw=True):
                check_block(i, usize if usize is not None else len(payload),
                            crc, payload)
            out[slot] = payload
            continue
        if len(payload) > engine.caps.blk_cap:
            out[slot] = _spec_host_fallback_item(
                engine, i, payload, usize, crc, st, sp)
            continue
        jobs.append((slot, i, usize, crc, payload,
                     usize if usize is not None else MAX_BLOCK))

    if not jobs:
        return out

    caps = engine.caps
    S = engine.shards
    slices = partition_blocks(len(jobs), S)
    per = [jobs[sl.start: sl.stop] for sl in slices]
    steps = max(len(p) for p in per)
    mb = engine.micro_batch
    fn = _sharded_plan_decode_compiled(
        engine.mesh, tuple(engine.shard_axes), caps.out_cap, caps.max_lit,
        caps.max_match, MAX_RESOLVE_ROUNDS, engine.use_pallas)

    def drain(meta, res):
        start, counts, r = meta
        rows, status, _crc = res
        stat = np.asarray(status)
        for si in range(S):
            for j in range(counts[si]):
                slot, idx, usize, crc, payload, _mo = per[si][start + j]
                row = si * r + j
                err = int(stat[row, kops.SPEC_ERR])
                if err:
                    raise FrameFormatError(
                        f"block {idx}: {_spec_err_message(err)}")
                if int(stat[row, kops.SPEC_OVERFLOW]):
                    out[slot] = _spec_host_fallback_item(
                        engine, idx, payload, usize, crc, st, sp)
                    continue
                out_size = int(stat[row, kops.SPEC_OUT_SIZE])
                if usize is not None and out_size != usize:
                    raise FrameFormatError(
                        f"block {idx}: decoded {out_size} bytes, "
                        f"table says {usize}")
                st.device_blocks += 1
                with sp("decode.drain", bytes=out_size):
                    data = np.asarray(rows[row][:out_size]).tobytes()
                st.host_bytes += out_size
                with sp("decode.verify", block=idx):
                    check_block(idx, out_size, crc, data)
                out[slot] = data

    inflight = None
    for start in range(0, steps, mb):
        counts = [max(0, min(mb, len(p) - start)) for p in per]
        r = pad_pow2_count(max(counts), mb)
        blk = np.zeros((S * r, caps.blk_cap + kops.SPEC_PAD), np.uint8)
        ns = np.zeros((S * r,), np.int32)
        mo = np.zeros((S * r,), np.int32)
        for si in range(S):
            for j in range(counts[si]):
                _slot, _idx, _usize, _crc, payload, max_out = per[si][start + j]
                row = si * r + j
                blk[row, : len(payload)] = np.frombuffer(payload, np.uint8)
                ns[row] = len(payload)
                mo[row] = max_out
        st.dispatches += 1
        with sp("decode.plan_device", rows=sum(counts), shards=S,
                executor="sharded"):
            res = fn(jnp.asarray(blk), jnp.asarray(ns), jnp.asarray(mo))
        if inflight is not None:
            drain(*inflight)
        inflight = ((start, counts, r), res)
    if inflight is not None:
        drain(*inflight)
    if ob:
        obs.registry().counter(
            "fabric.dispatches",
            "sharded compress/decode jit dispatches").inc(st.dispatches)
        obs.registry().counter(
            "fabric.fallback_blocks",
            "sharded-decode blocks executed on host "
            "(plan overflowed DevicePlanCaps)").inc(st.fallback_blocks)
    return out


# ---------------------------------------------------------------------------
# Provenance helpers.
# ---------------------------------------------------------------------------

def shard_subframe(frame: bytes, shard: int) -> bytes:
    """Extract one shard's blocks from a v4 frame as a standalone v3 frame.

    The fabric's core invariant made testable: for every shard,
    ``shard_subframe(v4_frame, s)`` must be byte-identical to
    ``LZ4Engine(<same config>).compress(slice_bytes)`` on that shard's
    slice of the input — no payload is re-encoded here, the bytes are
    lifted straight out of the container.
    """
    info = frame_info(frame)
    if info["shard_count"] is None:
        raise FrameFormatError("not a version-4 (sharded) frame")
    payloads, usizes, raws, crcs = [], [], [], []
    for b in info["blocks"]:
        if b["shard"] != shard:
            continue
        payloads.append(frame[b["offset"]: b["offset"] + b["csize"]])
        usizes.append(b["usize"])
        raws.append(b["raw"])
        crcs.append(b["crc"])
    return encode_frame(payloads, usizes, raws, checksums=crcs)
