"""Fault tolerance & straggler mitigation primitives.

At fleet scale the failure domains are: host death (checkpoint/restart),
slow hosts (straggler detection -> re-mesh request), and I/O stalls (async
checkpointing).  This module provides the host-side policy pieces; the
recovery path itself (restore + elastic reshard) lives in checkpoint.py and
is exercised end-to-end by launch/train.py --simulate-failure and
tests/test_system.py.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StepMonitor:
    """EMA step-time tracker; flags stragglers and emits re-mesh requests."""

    ema_alpha: float = 0.1
    straggler_factor: float = 3.0
    warmup_steps: int = 5
    ema: float | None = None
    steps: int = 0
    straggler_events: int = 0
    _t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> dict:
        dt = time.perf_counter() - self._t0
        self.steps += 1
        is_straggler = False
        if self.steps > self.warmup_steps and self.ema is not None:
            is_straggler = dt > self.straggler_factor * self.ema
            if is_straggler:
                self.straggler_events += 1
        if self.ema is None:
            self.ema = dt
        elif not is_straggler:  # don't poison the EMA with outliers
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
        return {"step_time": dt, "ema": self.ema, "straggler": is_straggler}

    def should_remesh(self, threshold: int = 3) -> bool:
        """Persistent stragglers -> ask the launcher for an elastic re-mesh."""
        return self.straggler_events >= threshold


# RestartPolicy was promoted to `repro.resilience.retry` (alongside the
# jittered RetryPolicy that generalizes it); this import is the deprecation
# alias keeping the old path working.
from repro.resilience.retry import RestartPolicy  # noqa: E402,F401


class SimulatedFailure(RuntimeError):
    """Injected by --simulate-failure to exercise the recovery path."""
