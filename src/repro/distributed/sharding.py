"""Mesh context + logical sharding rules for params, activations and caches.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * batch           -> ("pod","data")        (DP; pod is an outer DP axis)
  * q-heads, d_ff, experts' hidden, vocab -> "model"   (TP; GSPMD pads
    non-divisible head counts — whisper 12, minicpm 36)
  * FSDP: the non-TP big dimension of 2D+ weights -> "data" (ZeRO-3 style;
    XLA all-gathers on use, reduce-scatters grads)
  * decode KV caches: sequence axis -> "model" (32k) or ("data","model")
    (500k) — flash-decode style partial-softmax combine is inserted by SPMD.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.sharding import Mesh, NamedSharding

_STATE: dict[str, Any] = {"mesh": None}


def set_global_mesh(mesh: Mesh | None):
    _STATE["mesh"] = mesh


def get_mesh() -> Mesh | None:
    return _STATE["mesh"]


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = _STATE["mesh"]
    _STATE["mesh"] = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _STATE["mesh"] = prev


def shard_map_compat():
    """jax.shard_map across jax versions (one shim, shared by all callers)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    # Older jax: shard_map lives in jax.experimental and the check_vma
    # kwarg is spelled check_rep.
    from jax.experimental.shard_map import shard_map as legacy

    def fn(*args, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return legacy(*args, **kw)

    return fn


def make_mesh_compat(shape, axes, devices=None) -> Mesh:
    # axis_types (and jax.sharding.AxisType) only exist on newer jax; Auto is
    # the default there, so omitting it on older versions is equivalent.
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def single_device_mesh() -> Mesh:
    return make_mesh_compat((1, 1), ("data", "model"))


def batch_axes(mesh: Mesh | None = None, pure_dp: bool = False):
    mesh = mesh or get_mesh()
    if mesh is None:
        return ()
    names = ("pod", "data", "model") if pure_dp else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def sharding(spec: P, mesh: Mesh | None = None) -> NamedSharding:
    mesh = mesh or get_mesh()
    return NamedSharding(mesh, spec)


def constrain(x, *spec_elems):
    """with_sharding_constraint if a mesh is active (no-op otherwise)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec_elems)))


def constrain_batch(x, seq_shard: bool = False, pure_dp: bool = False):
    """Shard the leading (batch) axis over the DP axes; optionally also the
    sequence axis on "model" (Megatron-style sequence parallelism)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    ba = batch_axes(mesh, pure_dp)
    if pure_dp and x.shape[0] % (np.prod([mesh.shape[a] for a in ba]) or 1) != 0:
        ba = batch_axes(mesh)  # fall back when batch does not divide
    if seq_shard and not pure_dp and x.ndim >= 3 and x.shape[1] % mesh.shape["model"] == 0:
        spec = (ba, "model") + (None,) * (x.ndim - 2)
    else:
        spec = (ba,) + (None,) * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def local_batch(global_batch: int, mesh: Mesh | None = None) -> int:
    mesh = mesh or get_mesh()
    if mesh is None:
        return global_batch
    n = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)])) or 1
    assert global_batch % n == 0, (global_batch, n)
    return global_batch // n


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-based).
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, shape: tuple[int, ...], fsdp: bool, tp: int) -> P:
    """Sharding spec for one parameter, from its tree path and shape."""
    stacked = "segments" in path  # scanned params carry a leading repeats axis
    off = 1 if stacked else 0

    def fs(ax):  # data-axis (FSDP/ZeRO-3) shard for big dims only
        return "data" if (fsdp and shape[off + ax] >= 1024) else None

    name = path.split("/")[-2] if path.endswith("/w") or path.endswith("/b") else path.split("/")[-1]

    def pad(spec_tail: tuple) -> P:
        full = (None,) * off + spec_tail
        assert len(full) == len(shape), (path, shape, full)
        return P(*full)

    nd = len(shape) - off
    if path.endswith("/b") or nd == 1:  # biases, norms, scalars
        return pad((None,) * nd)
    if name in ("embed", "unembed"):
        # (V, d): vocab on model, d on data (fsdp)
        return pad(("model", fs(1)))
    if name in ("wq",):
        return pad((fs(0), "model"))
    if name in ("wk", "wv"):  # kv heads < TP on every assigned arch: replicate TP
        return pad((fs(0), None))
    if name in ("wo",):
        return pad(("model", fs(1)))
    if name in ("w_gate", "w_up", "w_in", "wx", "wgate", "wa", "wi_gate"):
        return pad((fs(0), "model"))
    if name in ("w_down", "w_out", "wo_proj"):
        return pad(("model", fs(1)))
    if name in ("w1", "w3"):  # MoE (E, d, F)
        return pad((None, fs(1), "model"))
    if name in ("w2",):       # MoE (E, F, d)
        return pad((None, "model", fs(2)))
    if name in ("wr",):       # router (d, E)
        return pad((None, None))
    if nd == 2:
        # generic 2D: TP on the trailing dim if it divides, FSDP on the other
        if shape[off + 1] % tp == 0 and shape[off + 1] >= tp:
            return pad((fs(0), "model"))
        return pad((fs(0), None))
    return pad((None,) * nd)


def param_specs(params_shape, fsdp: bool, mesh: Mesh | None = None, pure_dp: bool = False):
    """PyTree of PartitionSpecs matching a params (shape) tree."""
    mesh = mesh or get_mesh()
    tp = mesh.shape["model"] if mesh is not None else 1

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
            return type(tree)(out)
        shape = tuple(tree.shape)
        spec = _leaf_spec(path, shape, fsdp, tp)
        if pure_dp:  # no TP: drop "model"; widen FSDP shards to both axes
            elems = [None if el == "model" else el for el in spec]
            elems = [("data", "model") if el == "data" else el for el in elems]
            spec = P(*elems)
        return spec

    return walk(params_shape, "")


def param_shardings(params_shape, fsdp: bool, mesh: Mesh | None = None):
    mesh = mesh or get_mesh()
    specs = param_specs(params_shape, fsdp, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
