"""Seeded fault injection: deterministic corruption + I/O failure harness.

The resilience acceptance criteria are negative-space properties ("no
single-block corruption is ever silent", "a torn checkpoint write can never
be mistaken for a valid step") — they can only be tested by *injecting* the
failures.  This module is the single source of injected faults so every
test, benchmark ``--chaos`` run, and CI chaos leg draws from the same
deterministic generators:

Pure, seeded corruption helpers (no global state):

    flip_bits(data, seed, n)          n deterministic bit flips
    truncate(data, seed)              cut at a seeded point
    corrupt_frame_block(frame, i, s)  flip bits inside block i's payload only
    frame_payload_region(frame, i)    the [start, end) the above targets

Process-global failure injection (armed via `install`):

    with install(FaultInjector(seed=7, crash_at="checkpoint.rename")):
        checkpoint.save(...)          # dies mid-save, like SIGKILL

  * `crash_point(name)` — instrumented code calls this at named crash
    seams (checkpoint.save does); the armed injector detonates at its
    configured point by raising `InjectedCrash`.  Unarmed cost: one
    global None-check.
  * `io_point(name)` — instrumented I/O calls this; the injector can
    raise a transient `OSError` the first ``fail[name]`` times (proving
    the `resilience.retry` wrappers recover) or sleep ``slow[name]``
    seconds (I/O stall simulation).

Pytest: ``tests/conftest.py`` exposes this as the ``chaos`` fixture
(`chaos(seed=..., crash_at=...)` arms an injector for the test and
disarms on teardown).  Benchmarks: ``--chaos SEED`` in
benchmarks/resilience.py (and benchmarks/decode_parallel.py) drives the
same helpers.  CI runs the fixed seed matrix in both jax legs
(.github/workflows/ci.yml, chaos step).
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time

__all__ = ["InjectedCrash", "FaultInjector", "install", "active",
           "crash_point", "io_point", "flip_bits", "truncate",
           "corrupt_frame_block", "frame_payload_region"]


class InjectedCrash(RuntimeError):
    """A simulated process kill at a named crash point.

    RuntimeError (not BaseException) so test harnesses handle it normally,
    but raised from a point where the instrumented code performs no
    cleanup — the on-disk state it leaves behind is exactly what a SIGKILL
    at that seam would leave.
    """


@dataclasses.dataclass
class FaultInjector:
    """One armed set of deterministic faults (see module docstring).

    ``fail``: op name -> how many times `io_point(op)` raises a transient
    OSError before letting calls through (the retry loop's test surface).
    ``slow``: op name -> seconds each `io_point(op)` sleeps.
    ``crash_at``: crash-point name where `crash_point` raises
    `InjectedCrash` (once; the injector disarms its crash after firing so
    post-mortem recovery code can run under the same installation).
    """

    seed: int = 0
    crash_at: str | None = None
    fail: dict[str, int] = dataclasses.field(default_factory=dict)
    slow: dict[str, float] = dataclasses.field(default_factory=dict)
    # Observability for assertions: what actually fired.
    crashes: list[str] = dataclasses.field(default_factory=list)
    io_faults: list[str] = dataclasses.field(default_factory=list)
    slept_s: float = 0.0

    def __post_init__(self):
        self.rng = random.Random(self.seed)
        self._lock = threading.Lock()

    # -- corruption (instance-seeded wrappers over the pure helpers) -------

    def flip_bits(self, data: bytes, n: int = 1, start: int = 0,
                  end: int | None = None) -> bytes:
        return flip_bits(data, self.rng.randrange(2**31), n, start, end)

    def truncate(self, data: bytes) -> bytes:
        return truncate(data, self.rng.randrange(2**31))

    def corrupt_frame_block(self, frame: bytes, index: int,
                            n: int = 1) -> bytes:
        return corrupt_frame_block(frame, index, self.rng.randrange(2**31), n)

    # -- failure points -----------------------------------------------------

    def _crash(self, name: str) -> None:
        if self.crash_at == name:
            with self._lock:
                if self.crash_at != name:   # lost the race; already fired
                    return
                self.crash_at = None
                self.crashes.append(name)
            raise InjectedCrash(f"injected crash at {name!r}")

    def _io(self, name: str) -> None:
        delay = self.slow.get(name, 0.0)
        if delay:
            time.sleep(delay)
            self.slept_s += delay
        with self._lock:
            left = self.fail.get(name, 0)
            if left <= 0:
                return
            self.fail[name] = left - 1
            self.io_faults.append(name)
        raise OSError(f"injected transient I/O error at {name!r}")


_ACTIVE: FaultInjector | None = None
_INSTALL_LOCK = threading.Lock()


def active() -> FaultInjector | None:
    return _ACTIVE


@contextlib.contextmanager
def install(injector: FaultInjector):
    """Arm ``injector`` process-wide for the with-block (tests/benchmarks
    only; nested installs are a usage error)."""
    global _ACTIVE
    with _INSTALL_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultInjector is already installed")
        _ACTIVE = injector
    try:
        yield injector
    finally:
        _ACTIVE = None


def crash_point(name: str) -> None:
    """Named crash seam — a no-op unless an armed injector targets it."""
    inj = _ACTIVE
    if inj is not None:
        inj._crash(name)


def io_point(name: str) -> None:
    """Named I/O fault seam — a no-op unless an armed injector configures
    a transient failure or stall for it."""
    inj = _ACTIVE
    if inj is not None:
        inj._io(name)


# -- pure seeded corruption helpers -----------------------------------------

def flip_bits(data: bytes, seed: int, n: int = 1, start: int = 0,
              end: int | None = None) -> bytes:
    """Flip ``n`` deterministic bits of ``data[start:end]`` (distinct
    positions; same (data-length, seed, n, region) -> same output)."""
    end = len(data) if end is None else end
    if not 0 <= start < end <= len(data):
        raise ValueError(f"bad flip region [{start}, {end}) for {len(data)}")
    rng = random.Random(seed)
    out = bytearray(data)
    span = end - start
    n = min(n, span * 8)
    for pos in rng.sample(range(span * 8), n):
        out[start + pos // 8] ^= 1 << (pos % 8)
    return bytes(out)


def truncate(data: bytes, seed: int, min_keep: int = 1) -> bytes:
    """Cut ``data`` at a seeded point in [min_keep, len-1] — always drops
    at least one byte."""
    if len(data) <= min_keep:
        raise ValueError("nothing to truncate")
    rng = random.Random(seed)
    return data[: rng.randint(min_keep, len(data) - 1)]


def frame_payload_region(frame: bytes, index: int) -> tuple[int, int]:
    """[start, end) byte range of block ``index``'s stored payload inside
    ``frame`` — the region `corrupt_frame_block` flips (table/header stay
    intact, so damage is attributable to exactly that block)."""
    from repro.core.frame import frame_info  # lazy: avoid import cycles

    b = frame_info(frame)["blocks"][index]
    if b["csize"] == 0:
        raise ValueError(f"block {index} has an empty payload")
    return b["offset"], b["offset"] + b["csize"]


def corrupt_frame_block(frame: bytes, index: int, seed: int,
                        n: int = 1) -> bytes:
    """Flip ``n`` seeded bits inside block ``index``'s payload bytes."""
    start, end = frame_payload_region(frame, index)
    return flip_bits(frame, seed, n, start, end)
