"""Salvage decode: recover every undamaged block of a corrupted frame.

The strict decode paths are all-or-nothing — one flipped bit fails the
whole frame, by design ("corruption is never silent").  Salvage is the
recovery half of that contract: when a frame IS damaged, decode everything
the damage did not touch, reconstruct what the frame-v6 parity section can
prove correct, and return an exact accounting of what was lost:

    report = salvage_frame(frame)            # or FrameReader(f).salvage()
    report.data          # full-length content, lost blocks zero-filled
    report.ok            # block indices that decoded clean
    report.reconstructed # blocks rebuilt byte-identically from XOR parity
    report.lost          # blocks neither decode nor parity could save
    report.holes         # merged [start, end) decompressed ranges lost
    report.errors        # block index -> what was wrong with it

Three layers of recovery, in order:

  1. Tolerant structure parse (`frame.scan_frame`): keep every readable
     table entry even when the strict parse rejects the frame.
  2. Per-block decode + verify on the engine's configured executor —
     serial/thread/process blocks go through ONE error-capturing `_map`
     fan-out (the pool stays busy; a bad block fails only itself); the
     device executor decodes per block so one poisoned payload cannot
     sink a stacked micro-batch.
  3. Frame-v6 parity reconstruction (`frame.xor_bytes`): any SINGLE failed
     block per parity group is rebuilt from the group's parity payload +
     surviving stored payloads, then RE-VALIDATED through the normal
     decode + `check_block` path — a reconstruction that cannot be proven
     byte-identical is counted lost, never returned.

Nothing in the report is guessed: ``data`` holes are zero-filled and
listed in ``holes``; `content_crc_ok` is only True when the whole object
re-verified against the v5/v6 trailer.  Counted through `repro.obs` when
telemetry is on: ``resilience.salvaged_blocks`` / ``reconstructed_blocks``
/ ``lost_blocks``.  Failure-mode table: docs/resilience.md.
"""
from __future__ import annotations

import dataclasses

from repro import obs

from .errors import FrameError

__all__ = ["SalvageReport", "salvage_frame"]


def _salvage_block_task(args):
    """Decode + verify one block, CAPTURING failure instead of raising
    (module-level so it pickles for the process pool).  Returns
    ``(data | None, err_message | None, cause | None)``."""
    from repro.core.decode_engine import _decode_one
    from repro.core.decoder import LZ4FormatError
    from repro.core.frame import check_block

    payload, usize, crc, index, raw, two_phase, ob = args
    try:
        data = payload if raw else _decode_one(payload, usize, two_phase, ob)
        check_block(index, usize, crc, data)
        return data, None, None
    except LZ4FormatError as e:          # includes FrameFormatError
        return None, str(e), getattr(e, "cause", None) or "parse"


@dataclasses.dataclass
class SalvageReport:
    """What a salvage pass recovered — and exactly what it could not.

    ``data`` is always ``content_size`` bytes long when the header said so
    (lost regions zero-filled); ``holes`` are the merged decompressed
    [start, end) ranges those zeros cover, so a caller can overlay
    recovered bytes onto a previous good copy.  ``errors`` maps each
    damaged block to the error that condemned it (reconstructed blocks
    keep their original error, annotated); ``notes`` carries structural
    anomalies from the tolerant parse.  ``content_crc_ok`` is True only
    when the FULL object re-verified against the frame trailer — None
    when there is no trailer or the object has holes.
    """

    data: bytes
    block_count: int
    ok: list[int]
    reconstructed: list[int]
    lost: list[int]
    holes: list[tuple[int, int]]
    errors: dict[int, str]
    notes: list[str]
    content_crc_ok: bool | None

    @property
    def complete(self) -> bool:
        """True when every block was recovered (decoded or reconstructed)."""
        return not self.lost and len(self.ok) + len(self.reconstructed) \
            == self.block_count


def _decode_blocks_capturing(engine, frame, blocks, ok_idx, st):
    """Per-block decode of ``ok_idx`` on the engine's executor, capturing
    failures.  Returns ``{index: data}`` and ``{index: (msg, cause)}``."""
    got: dict[int, bytes] = {}
    bad: dict[int, tuple[str, str]] = {}
    if engine.executor == "device":
        # Per-block dispatches: one poisoned payload must only fail itself,
        # and the device path raises out of a whole stacked micro-batch.
        for i in ok_idx:
            b = blocks[i]
            try:
                got[i] = bytes(memoryview(
                    engine._decode_entries_device(
                        frame, [(i, b)], to_device=False, verify=True,
                        st=st)[0]))
            except FrameError as e:
                bad[i] = (str(e), getattr(e, "cause", None) or "parse")
        return got, bad
    ob = engine._obs_on()
    args = []
    for i in ok_idx:
        b = blocks[i]
        payload = frame[b["offset"]: b["offset"] + b["csize"]]
        args.append((payload, b["usize"], b["crc"], i, b["raw"],
                     engine.two_phase, ob))
    for i, (data, msg, cause) in zip(
            ok_idx, engine._map(_salvage_block_task, args, st)):
        if data is not None:
            got[i] = data
        else:
            bad[i] = (msg, cause)
    return got, bad


def _reconstruct_from_parity(frame, info, failed, engine):
    """Rebuild single-failure parity groups.  Returns ``{index: data}``
    (verified decoded content) and ``{index: note}`` for groups parity
    could not save."""
    from repro.core.decode_engine import _decode_one
    from repro.core.decoder import LZ4FormatError
    from repro.core.frame import block_crc, check_block, xor_bytes

    pg, parity = info["parity_group"], info["parity"]
    blocks = info["blocks"]
    rebuilt: dict[int, bytes] = {}
    why_not: dict[int, str] = {}
    if not pg or not parity:
        return rebuilt, why_not
    for i in sorted(failed):
        g = i // pg
        if g >= len(parity):
            why_not[i] = "parity group missing"
            continue
        group = range(g * pg, min((g + 1) * pg, len(blocks)))
        others = [j for j in group if j != i and j in failed]
        if others:
            why_not[i] = (f"parity group {g} has {1 + len(others)} damaged "
                          "blocks (XOR parity reconstructs one)")
            continue
        p = parity[g]
        if not p.get("ok", True):
            why_not[i] = f"parity group {g} unreadable"
            continue
        ppayload = frame[p["offset"]: p["offset"] + p["plen"]]
        if block_crc(ppayload) != p["crc"]:
            why_not[i] = f"parity group {g} payload failed its CRC"
            continue
        surviving = []
        usable = True
        for j in group:
            if j == i:
                continue
            b = blocks[j]
            if not b.get("ok", True) or b["csize"] > p["plen"]:
                why_not[i] = f"block {j}'s stored payload is unreadable"
                usable = False
                break
            surviving.append(frame[b["offset"]: b["offset"] + b["csize"]])
        if not usable:
            continue
        b = blocks[i]
        payload = xor_bytes([ppayload] + surviving, p["plen"])[: b["csize"]]
        # Never trust a reconstruction: prove it by decoding + the normal
        # per-block size/CRC check.  Overlapping damage (parity AND a
        # survivor both flipped, CRCs colliding) fails here, not silently.
        try:
            if b["raw"]:
                data = payload
            else:
                data = _decode_one(payload, b["usize"],
                                   engine.two_phase, False)
            check_block(i, b["usize"], b["crc"], data)
        except LZ4FormatError as e:
            why_not[i] = f"reconstruction failed verification: {e}"
            continue
        rebuilt[i] = data
    return rebuilt, why_not


def salvage_frame(frame: bytes, engine=None) -> SalvageReport:
    """Decode every undamaged block of ``frame``; reconstruct what v6
    parity can prove; report the rest (module docstring has the layers).

    ``engine`` is the `LZ4DecodeEngine` whose executor runs the per-block
    decodes (default: the process-wide engine).  Raises `FrameError` only
    when there is no block table to salvage with (header too short, bad
    magic, unknown version).
    """
    from repro.core.decode_engine import DecodeStats, default_decode_engine
    from repro.core.frame import block_crc, scan_frame

    eng = engine or default_decode_engine()
    ob = eng._obs_on()
    sp = obs.span_factory(ob)
    with sp("salvage.total", bytes_in=len(frame)):
        info = scan_frame(frame)
        blocks = info["blocks"]
        notes = list(info["notes"])
        st = DecodeStats(bytes_in=len(frame), blocks=len(blocks))
        errors: dict[int, str] = {}
        failed: set[int] = set()
        for i, b in enumerate(blocks):
            if not b.get("ok", True):
                errors[i] = b["note"]
                failed.add(i)
        with sp("salvage.decode", blocks=len(blocks) - len(failed)):
            got, bad = _decode_blocks_capturing(
                eng, frame, blocks,
                [i for i in range(len(blocks)) if i not in failed], st)
        for i, (msg, _cause) in bad.items():
            errors[i] = msg
            failed.add(i)
        with sp("salvage.reconstruct", candidates=len(failed)):
            rebuilt, why_not = _reconstruct_from_parity(frame, info, failed,
                                                        eng)
        for i, data in rebuilt.items():
            got[i] = data
            failed.discard(i)
            errors[i] += " (reconstructed from parity)"
        for i, why in why_not.items():
            errors[i] += f"; {why}"
        # Assemble: table-ordered content, zero-filling losses; extend to
        # the header content_size when the table itself lost entries.
        parts, holes, pos = [], [], 0
        for i, b in enumerate(blocks):
            u = b["usize"]
            if i in got:
                parts.append(got[i])
            else:
                parts.append(b"\x00" * u)
                holes.append((pos, pos + u))
            pos += u
        if info["content_size"] is not None and pos < info["content_size"]:
            missing = info["content_size"] - pos
            parts.append(b"\x00" * missing)
            holes.append((pos, pos + missing))
            notes.append(f"zero-filled {missing} bytes past the readable "
                         "table (lost entries)")
        data = b"".join(parts)
        merged: list[tuple[int, int]] = []
        for s, e in holes:
            if merged and merged[-1][1] == s:
                merged[-1] = (merged[-1][0], e)
            else:
                merged.append((s, e))
        lost = sorted(failed)
        crc_ok = None
        if info["content_crc"] is not None and not lost \
                and len(got) == info["block_count"]:
            crc_ok = block_crc(data) == info["content_crc"]
        ok = sorted(set(got) - set(rebuilt))
        if ob:
            r = obs.registry()
            r.counter("resilience.salvage_calls", "salvage passes").inc()
            r.counter("resilience.salvaged_blocks",
                      "blocks recovered clean by salvage").inc(len(ok))
            r.counter("resilience.reconstructed_blocks",
                      "blocks rebuilt from v6 parity").inc(len(rebuilt))
            r.counter("resilience.lost_blocks",
                      "blocks salvage could not recover").inc(len(lost))
        st.bytes_out = len(data)
        eng._finish_call(st)
        return SalvageReport(
            data=data, block_count=info["block_count"], ok=ok,
            reconstructed=sorted(rebuilt), lost=lost, holes=merged,
            errors=errors, notes=notes, content_crc_ok=crc_ok,
        )
