"""`repro.resilience` — error recovery for the read/write stack.

The detect-only era (per-block CRC32 since frame v2, whole-object trailer
since v5) made corruption *never silent*; this package makes it
*survivable*:

    errors    FrameError — unified corruption hierarchy with structured
              block_index/cause attributes (LZ4FormatError, FrameFormatError
              and CheckpointError are all subclasses now).
    retry     RetryPolicy/call — decorrelated-jitter backoff with deadline
              caps, wrapped around checkpoint and offload I/O; the promoted
              home of RestartPolicy (old import path still works).
    salvage   SalvageReport + salvage_frame — decode every undamaged block
              of a corrupted frame via the seek index (all four executors)
              and reconstruct single damaged blocks from frame-v6 parity.
    inject    Seeded fault injection: deterministic bit flips, truncations,
              torn renames, transient OSErrors, crash points (the `chaos`
              pytest fixture and benchmark ``--chaos`` flags).

Salvage semantics, parity math, and the failure-mode table:
docs/resilience.md.

NOTE This ``__init__`` loads submodules lazily (PEP 562): `repro.core`
imports `repro.resilience.errors` at module-import time, and eagerly
importing `salvage` here would close an import cycle back through
`repro.core.decode_engine`.
"""
from __future__ import annotations

from .errors import FrameError  # noqa: F401  (dependency-free, safe eager)

__all__ = [
    "FrameError",
    "RetryPolicy", "RestartPolicy", "call", "retrying",
    "SalvageReport", "salvage_frame",
    "FaultInjector", "InjectedCrash",
    "errors", "retry", "salvage", "inject",
]

_LAZY = {
    "RetryPolicy": ("retry", "RetryPolicy"),
    "RestartPolicy": ("retry", "RestartPolicy"),
    "call": ("retry", "call"),
    "retrying": ("retry", "retrying"),
    "SalvageReport": ("salvage", "SalvageReport"),
    "salvage_frame": ("salvage", "salvage_frame"),
    "FaultInjector": ("inject", "FaultInjector"),
    "InjectedCrash": ("inject", "InjectedCrash"),
    "errors": ("errors", None),
    "retry": ("retry", None),
    "salvage": ("salvage", None),
    "inject": ("inject", None),
}


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(f".{mod_name}", __name__)
    return mod if attr is None else getattr(mod, attr)
