"""Jittered-backoff retries for transient I/O failures.

Generalizes the old `distributed.fault.RestartPolicy` (which survives here,
unchanged in behaviour, with a deprecation alias at its old import path):
where `RestartPolicy` only *budgets* failures and hands back a sleep time,
`RetryPolicy` + `call` actually drive the retry loop — decorrelated-jitter
backoff (Brooker, "Exponential Backoff And Jitter": each sleep is drawn
uniformly from ``[base, prev * multiplier]`` instead of marching a
deterministic doubling ladder that synchronizes a fleet's retry storms),
a hard attempt budget, and an optional wall-clock deadline cap so a
retried operation can never outlive its caller's patience.

Used by checkpoint save/restore I/O and the offload/restore read paths;
the fault-injection harness (`repro.resilience.inject`) raises transient
`OSError`s through these wrappers to prove the loop recovers.  Every
retry is counted through `repro.obs` (``resilience.retries``) when
telemetry is on.

Determinism: pass ``seed`` to pin the jitter sequence (tests and the
seeded chaos matrix do), and ``sleep=`` to capture sleeps instead of
paying them.
"""
from __future__ import annotations

import dataclasses
import random
import time

from repro import obs

__all__ = ["RetryPolicy", "RestartPolicy", "call", "retrying"]


@dataclasses.dataclass
class RetryPolicy:
    """Retry budget + decorrelated-jitter backoff schedule.

    ``max_attempts`` counts the FIRST try: ``max_attempts=4`` means one
    attempt plus up to three retries.  ``deadline_s`` caps the total time
    from the first attempt — a retry is abandoned (and the last error
    re-raised) when the budget is spent or the next sleep would cross the
    deadline.  ``retry_on`` is the exception allowlist; anything else
    propagates immediately (corruption errors are NOT transient — never
    put `FrameError` here).
    """

    max_attempts: int = 4
    base_s: float = 0.02
    cap_s: float = 1.0
    multiplier: float = 3.0
    deadline_s: float | None = None
    retry_on: tuple[type[BaseException], ...] = (OSError,)
    seed: int | None = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_s < 0 or self.cap_s < self.base_s:
            raise ValueError("need 0 <= base_s <= cap_s")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1.0")

    def backoffs(self):
        """Yield the sleep schedule: decorrelated jitter, capped at
        ``cap_s`` (yields ``max_attempts - 1`` sleeps)."""
        rng = random.Random(self.seed)
        prev = self.base_s
        for _ in range(self.max_attempts - 1):
            prev = min(self.cap_s,
                       rng.uniform(self.base_s, max(self.base_s,
                                                    prev * self.multiplier)))
            yield prev


def call(fn, *args, policy: RetryPolicy | None = None, sleep=time.sleep,
         on_retry=None, clock=time.monotonic, **kwargs):
    """Run ``fn(*args, **kwargs)``, retrying transient failures per policy.

    ``on_retry(attempt, exc, delay)`` (optional) observes each retry —
    the chaos benchmark logs through it.  Raises the LAST transient error
    once the attempt budget or deadline is spent; non-``retry_on``
    exceptions propagate immediately, un-retried.
    """
    pol = policy or RetryPolicy()
    start = clock()
    backoffs = pol.backoffs()
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn(*args, **kwargs)
        except pol.retry_on as e:
            delay = next(backoffs, None)
            if delay is None:
                raise
            if pol.deadline_s is not None \
                    and clock() - start + delay > pol.deadline_s:
                raise
            if obs.is_enabled():
                obs.counter("resilience.retries",
                            "transient-failure retries").inc()
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)


def retrying(policy: RetryPolicy | None = None, sleep=time.sleep):
    """Decorator form of `call` (same semantics, fixed policy)."""
    def deco(fn):
        def wrapped(*args, **kwargs):
            return call(fn, *args, policy=policy, sleep=sleep, **kwargs)
        wrapped.__name__ = getattr(fn, "__name__", "retrying")
        wrapped.__doc__ = fn.__doc__
        return wrapped
    return deco


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-retry policy with exponential backoff.

    Promoted here from `repro.distributed.fault` (a deprecation alias
    remains at the old path).  Deliberately minimal — it budgets failures
    and hands back a sleep; the caller owns the loop.  New code should
    prefer `RetryPolicy` + `call`, which add jitter and a deadline.
    """

    max_failures: int = 5
    backoff_s: float = 1.0
    failures: int = 0

    def record_failure(self) -> float:
        """Returns backoff seconds to sleep; raises if the budget is spent."""
        self.failures += 1
        if self.failures > self.max_failures:
            raise RuntimeError(f"giving up after {self.failures - 1} failures")
        return self.backoff_s * (2 ** (self.failures - 1))
