"""`FrameError` — the root of the repo's corruption-error hierarchy.

Before the resilience layer, the read stack raised a mix of types that
callers had to string-match: block parse errors were `LZ4FormatError`
(a bare ValueError subclass), frame/table/CRC errors `FrameFormatError`,
and checkpoint corruption a `CheckpointError(RuntimeError)` wrapping the
others' messages.  `FrameError` unifies them:

    FrameError                      (this module; carries block_index/cause)
      LZ4FormatError(ValueError)    (core/decoder.py — block parse errors)
        FrameFormatError            (core/frame.py — frame/table/CRC errors)
      CheckpointError(RuntimeError) (checkpoint/checkpoint.py)

Every pre-existing `except ValueError` / `except RuntimeError` site keeps
working (the legacy bases are retained via multiple inheritance), and every
corruption path — parse, CRC, truncation, checkpoint — is now catchable as
one type with structured attributes instead of message matching:

    try:
        engine.decode(frame)
    except FrameError as e:
        print(e.block_index, e.cause)   # e.g. 3, "crc"

``block_index`` is the 0-based frame/leaf block the error was attributed
to (None for whole-frame errors: header, table, content trailer).
``cause`` is a short machine-readable slug — the salvage layer
(`repro.resilience.salvage`) groups per-block failures by it:

    "truncated"    payload/table/header bytes missing
    "parse"        token stream does not parse as LZ4
    "size"         decoded size disagrees with the table/manifest
    "crc"          per-block content CRC32 mismatch
    "content_crc"  whole-object (v5+) trailer mismatch
    "structure"    frame/table structure invalid (magic, version, flags)

Error MESSAGES are unchanged everywhere — tests pin them — the hierarchy
only adds attributes and a common base.

This module is dependency-free (stdlib only) so `repro.core.decoder` can
import it without cycling back through the resilience package's heavier
submodules (the package ``__init__`` loads those lazily).
"""
from __future__ import annotations

__all__ = ["FrameError"]


class FrameError(Exception):
    """Base class for every corruption/format error in the read stack.

    Subclasses keep their legacy bases (ValueError for the block/frame
    parsers, RuntimeError for checkpoints) so existing handlers and tests
    are unaffected; the attributes here are additive.
    """

    def __init__(self, *args, block_index: int | None = None,
                 cause: str | None = None):
        super().__init__(*args)
        self.block_index = block_index
        self.cause = cause

    def __reduce__(self):
        # Exceptions cross process-pool boundaries (the decode engine's
        # "process" executor): keep args + structured attributes through
        # pickling.  BaseException's default reduce already ships __dict__
        # as state, but only when the subclass __init__ accepts bare args —
        # which ours does — so this explicit form is just belt-and-braces
        # against subclasses overriding __init__ incompatibly.
        return (self.__class__, self.args,
                {"block_index": self.block_index, "cause": self.cause})

    def __setstate__(self, state):
        self.__dict__.update(state)
