"""Fault-tolerant checkpointing with LZ4 block compression (the paper's engine
as a first-class substrate feature).

Layout (atomic: written to <dir>.tmp then os.rename'd):
    ckpt_<step>/
      manifest.json   # tree structure, shapes, dtypes, per-leaf block index,
                      # crc32 checksums, compressed sizes
      data.bin        # concatenated (possibly LZ4-compressed) 64 KB blocks

Properties:
  * every leaf is chunked into 64 KB blocks and compressed with the JAX
    engine (paper's combined scheme); incompressible blocks are stored raw
    (per-block flag) so worst-case overhead is ~0;
  * restore is sharding-agnostic: leaves are rebuilt as numpy and device_put
    against whatever mesh/shardings the *current* job uses (elastic restart);
  * restore decodes each leaf's independent blocks in parallel through the
    `LZ4DecodeEngine` (two-phase plan/execute decode) instead of a serial
    Python byte loop;
  * async saves: a snapshot is device_get'd synchronously, then written on a
    background thread so the train loop never blocks on I/O;
  * corrupt checkpoints (bad checksum / truncation) raise CheckpointError and
    the training driver falls back to the previous checkpoint.
"""
from __future__ import annotations

import binascii
import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.decode_engine import default_decode_engine
from repro.core.decoder import LZ4FormatError
from repro.core.engine import default_engine
from repro.core.lz4_types import MAX_BLOCK


class CheckpointError(RuntimeError):
    pass


def _flatten(tree, path=""):
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _flatten(tree[k], f"{path}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{path}/{i}")
    elif tree is None:
        return
    else:
        yield path, tree


def _compress_leaf(raw: bytes, use_jax: bool,
                   engine=None) -> tuple[list[tuple[bool, bytes]], int]:
    chunks = [raw[i : i + MAX_BLOCK] for i in range(0, max(len(raw), 1), MAX_BLOCK)]
    # One engine call per leaf: all of the leaf's blocks go through
    # micro-batched dispatches instead of one jit call per 64 KB chunk.
    # A sharded engine (LZ4Engine(mesh=...) / shards=N) partitions the
    # leaf's block stack across the fabric; the output block list is
    # identical either way (global order, no framing).
    lz_blocks = (
        (engine or default_engine()).compress_to_blocks(raw)
        if use_jax and len(raw) >= 1024 else None
    )
    blocks = []
    comp_total = 0
    for i, chunk in enumerate(chunks):
        lz = lz_blocks[i] if lz_blocks is not None else None
        if lz is not None and len(lz) < len(chunk):
            blocks.append((True, lz))
            comp_total += len(lz)
        else:
            blocks.append((False, chunk))
            comp_total += len(chunk)
    return blocks, comp_total


def save(ckpt_dir: str, step: int, tree, *, compress: bool = True,
         async_write: bool = False, keep_last: int = 3, engine=None):
    """Write a checkpoint. Returns the final path (or a Thread if async).

    `engine`: optional `LZ4Engine` override — e.g. a sharded engine
    (``LZ4Engine(mesh=...)``) so each leaf's block stack compresses across
    the mesh fabric instead of one device.  Block bytes are identical
    either way, so checkpoints stay interchangeable.
    """
    # Snapshot synchronously (cheap device_get), write possibly in background.
    with obs.span("checkpoint.snapshot", step=step):
        leaves = [(p, np.asarray(jax.device_get(x))) for p, x in _flatten(tree)]

    def _write():
        t0 = time.perf_counter()
        raw_total = 0
        final = os.path.join(ckpt_dir, f"ckpt_{step}")
        tmp = final + ".tmp"
        with obs.span("checkpoint.save", step=step, leaves=len(leaves)):
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            with open(os.path.join(tmp, "data.bin"), "wb") as f:
                for path, arr in leaves:
                    raw = arr.tobytes()
                    raw_total += len(raw)
                    blocks, _ = _compress_leaf(raw, compress, engine)
                    entry = {
                        "path": path,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "raw_size": len(raw),
                        "crc32": binascii.crc32(raw) & 0xFFFFFFFF,
                        "blocks": [],
                    }
                    for is_comp, data in blocks:
                        entry["blocks"].append(
                            {"offset": f.tell(), "size": len(data), "lz4": bool(is_comp)}
                        )
                        f.write(data)
                    manifest["leaves"].append(entry)
                data_bytes = f.tell()
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _cleanup(ckpt_dir, keep_last)
        if obs.is_enabled():
            obs.counter("checkpoint.saves", "checkpoints written").inc()
            obs.counter("checkpoint.save_bytes_raw",
                        "leaf bytes snapshotted").inc(raw_total)
            obs.counter("checkpoint.save_bytes_written",
                        "data.bin bytes written").inc(data_bytes)
            obs.histogram("checkpoint.save_seconds",
                          help="checkpoint write latency").observe(
                time.perf_counter() - t0)
        return final

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _cleanup(ckpt_dir: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None,
            decode_engine=None):
    """Rebuild the tree of `like` (a pytree of arrays or ShapeDtypeStructs).

    `shardings`: optional matching pytree of NamedShardings for elastic
    restore onto the current mesh.
    `decode_engine`: optional `LZ4DecodeEngine` override — e.g. an
    ``executor="process"`` engine for multi-core restores, or
    ``executor="device"`` to run block decompression inside the jit graph
    (plan on host, execute on accelerator) instead of in host NumPy.
    """
    t0 = time.perf_counter()
    eng = decode_engine or default_decode_engine()
    final = os.path.join(ckpt_dir, f"ckpt_{step}")
    man_path = os.path.join(final, "manifest.json")
    if not os.path.exists(man_path):
        raise CheckpointError(f"missing manifest: {man_path}")
    with open(man_path) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    data_path = os.path.join(final, "data.bin")
    out_leaves = {}
    raw_total = 0
    with obs.span("checkpoint.restore", step=step), open(data_path, "rb") as f:
        for path, spec in _flatten(like):
            if path not in by_path:
                raise CheckpointError(f"leaf {path} not in checkpoint")
            e = by_path[path]
            payloads, raws = [], []
            for b in e["blocks"]:
                f.seek(b["offset"])
                data = f.read(b["size"])
                if len(data) != b["size"]:
                    raise CheckpointError(f"truncated block in {path}")
                payloads.append(data)
                raws.append(not b["lz4"])
            # A leaf's blocks are independent: the decode engine plans and
            # executes them across its worker pool (or, with the device
            # executor, inside vmapped jit dispatches) instead of a loop.
            try:
                raw = b"".join(eng.decode_blocks(payloads, raws))
            except LZ4FormatError as err:
                raise CheckpointError(f"corrupt block in {path}: {err}") from err
            with obs.span("decode.verify", leaf=path):
                if binascii.crc32(bytes(raw)) & 0xFFFFFFFF != e["crc32"]:
                    raise CheckpointError(f"checksum mismatch for {path}")
            raw_total += len(raw)
            arr = np.frombuffer(bytes(raw), dtype=np.dtype(e["dtype"])).reshape(e["shape"])
            out_leaves[path] = arr
    if obs.is_enabled():
        obs.counter("checkpoint.restores", "checkpoints restored").inc()
        obs.counter("checkpoint.restore_bytes_raw",
                    "leaf bytes restored").inc(raw_total)
        obs.histogram("checkpoint.restore_seconds",
                      help="checkpoint restore latency").observe(
            time.perf_counter() - t0)

    def rebuild(tree, path=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{path}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{path}/{i}") for i, v in enumerate(tree))
        if tree is None:
            return None
        return out_leaves[path]

    host_tree = rebuild(like)
    if shardings is not None:
        host_tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            host_tree, shardings,
        )
    else:
        host_tree = jax.tree.map(jax.device_put, host_tree)
    return host_tree, manifest["step"]
