"""Fault-tolerant checkpointing with LZ4 block compression (the paper's engine
as a first-class substrate feature).

Layout (atomic: written to <dir>.tmp then os.rename'd):
    ckpt_<step>/
      manifest.json   # tree structure, shapes, dtypes, per-leaf block index,
                      # crc32 checksums, compressed sizes
      data.bin        # concatenated (possibly LZ4-compressed) 64 KB blocks

Properties:
  * every leaf is chunked into 64 KB blocks and compressed with the JAX
    engine (paper's combined scheme); incompressible blocks are stored raw
    (per-block flag) so worst-case overhead is ~0;
  * restore is sharding-agnostic: leaves are rebuilt as numpy and device_put
    against whatever mesh/shardings the *current* job uses (elastic restart);
  * restore decodes each leaf's independent blocks in parallel through the
    `LZ4DecodeEngine` (two-phase plan/execute decode) instead of a serial
    Python byte loop;
  * async saves: a snapshot is device_get'd synchronously, then written on a
    background thread so the train loop never blocks on I/O;
  * corrupt checkpoints (bad checksum / truncation) raise CheckpointError and
    the training driver falls back to the previous checkpoint —
    `restore_with_fallback` automates exactly that walk, and
    ``restore(..., on_error="salvage")`` recovers every undamaged block of
    a corrupt checkpoint (zero-filling the rest, with a full accounting);
  * saves are CRASH-CONSISTENT: data and manifest are written into
    ``ckpt_<step>.tmp``, fsync'd (files, then the tmp dir, then the parent
    after the rename), and atomically renamed into place — a writer killed
    at ANY point leaves either the previous complete checkpoint set or the
    new complete checkpoint, never a half-written step that `latest_step` /
    `restore` could mistake for valid (kill-in-the-middle tests pin this,
    via the named `crash_point` seams below);
  * the manifest carries content digests of the written artifact itself
    (``data_size`` / ``data_crc32`` over data.bin, per-leaf ``comp_crc32``
    over the stored block bytes), so restore detects torn or stale data
    BEFORE attempting any decode;
  * transient I/O failures (flaky NFS, injected via `repro.resilience.
    inject`) are retried with decorrelated-jitter backoff
    (`repro.resilience.retry`) around file opens and block reads.
"""
from __future__ import annotations

import binascii
import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.decode_engine import default_decode_engine
from repro.core.decoder import LZ4FormatError
from repro.core.engine import default_engine
from repro.core.lz4_types import MAX_BLOCK
from repro.resilience import retry as _retry
from repro.resilience.errors import FrameError
from repro.resilience.inject import crash_point, io_point


class CheckpointError(FrameError, RuntimeError):
    """Corrupt, torn, or unrestorable checkpoint.

    RuntimeError for backwards compatibility; `FrameError` joins it to the
    unified corruption hierarchy (structured ``cause`` attribute) so one
    handler covers frame and checkpoint damage."""


# Transient-I/O retry schedule for checkpoint file opens and block reads
# (seeded: the chaos tests pin its behaviour; cap small — this guards
# against flaky mounts, not outages).
_IO_RETRY = _retry.RetryPolicy(max_attempts=4, base_s=0.01, cap_s=0.2, seed=0)


def _open_retrying(path: str, mode: str):
    """`open` with transient-failure retries (io_point: checkpoint.open)."""
    def attempt():
        io_point("checkpoint.open")
        return open(path, mode)
    return _retry.call(attempt, policy=_IO_RETRY)


def _fsync_dir(path: str) -> None:
    """fsync a DIRECTORY so its entry mutations (create/rename) are durable
    — rename atomicity alone does not survive power loss without this."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree, path=""):
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _flatten(tree[k], f"{path}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{path}/{i}")
    elif tree is None:
        return
    else:
        yield path, tree


def _compress_leaf(raw: bytes, use_jax: bool,
                   engine=None) -> tuple[list[tuple[bool, bytes]], int]:
    chunks = [raw[i : i + MAX_BLOCK] for i in range(0, max(len(raw), 1), MAX_BLOCK)]
    # One engine call per leaf: all of the leaf's blocks go through
    # micro-batched dispatches instead of one jit call per 64 KB chunk.
    # A sharded engine (LZ4Engine(mesh=...) / shards=N) partitions the
    # leaf's block stack across the fabric; the output block list is
    # identical either way (global order, no framing).
    lz_blocks = (
        (engine or default_engine()).compress_to_blocks(raw)
        if use_jax and len(raw) >= 1024 else None
    )
    blocks = []
    comp_total = 0
    for i, chunk in enumerate(chunks):
        lz = lz_blocks[i] if lz_blocks is not None else None
        if lz is not None and len(lz) < len(chunk):
            blocks.append((True, lz))
            comp_total += len(lz)
        else:
            blocks.append((False, chunk))
            comp_total += len(chunk)
    return blocks, comp_total


def save(ckpt_dir: str, step: int, tree, *, compress: bool = True,
         async_write: bool = False, keep_last: int = 3, engine=None):
    """Write a checkpoint. Returns the final path (or a Thread if async).

    `engine`: optional `LZ4Engine` override — e.g. a sharded engine
    (``LZ4Engine(mesh=...)``) so each leaf's block stack compresses across
    the mesh fabric instead of one device.  Block bytes are identical
    either way, so checkpoints stay interchangeable.
    """
    # Snapshot synchronously (cheap device_get), write possibly in background.
    with obs.span("checkpoint.snapshot", step=step):
        leaves = [(p, np.asarray(jax.device_get(x))) for p, x in _flatten(tree)]

    def _write():
        t0 = time.perf_counter()
        raw_total = 0
        final = os.path.join(ckpt_dir, f"ckpt_{step}")
        tmp = final + ".tmp"
        with obs.span("checkpoint.save", step=step, leaves=len(leaves)):
            # A stale .tmp is debris from a previous writer killed mid-save
            # (the kill-in-the-middle tests produce exactly this); it is
            # never restorable state, so replace it wholesale.
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            data_crc = 0
            with _open_retrying(os.path.join(tmp, "data.bin"), "wb") as f:
                for path, arr in leaves:
                    raw = arr.tobytes()
                    raw_total += len(raw)
                    blocks, _ = _compress_leaf(raw, compress, engine)
                    entry = {
                        "path": path,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "raw_size": len(raw),
                        "crc32": binascii.crc32(raw) & 0xFFFFFFFF,
                        "comp_crc32": 0,
                        "blocks": [],
                    }
                    comp_crc = 0
                    for is_comp, data in blocks:
                        entry["blocks"].append(
                            {"offset": f.tell(), "size": len(data), "lz4": bool(is_comp)}
                        )
                        f.write(data)
                        comp_crc = binascii.crc32(data, comp_crc)
                        data_crc = binascii.crc32(data, data_crc)
                    entry["comp_crc32"] = comp_crc & 0xFFFFFFFF
                    manifest["leaves"].append(entry)
                    # Crash seam: data.bin torn mid-leaf, no manifest yet.
                    crash_point("checkpoint.data")
                data_bytes = f.tell()
                f.flush()
                os.fsync(f.fileno())
            # Digests of the artifact itself: restore verifies the bytes it
            # reads ARE the bytes this writer wrote, before any decode.
            manifest["data_size"] = data_bytes
            manifest["data_crc32"] = data_crc & 0xFFFFFFFF
            # Crash seam: complete data.bin, manifest never written.
            crash_point("checkpoint.manifest")
            with _open_retrying(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(tmp)
            # Crash seam: complete .tmp, never renamed into place.
            crash_point("checkpoint.rename")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _fsync_dir(ckpt_dir)
            # Crash seam: renamed, old checkpoints not yet pruned.
            crash_point("checkpoint.cleanup")
            _cleanup(ckpt_dir, keep_last)
        if obs.is_enabled():
            obs.counter("checkpoint.saves", "checkpoints written").inc()
            obs.counter("checkpoint.save_bytes_raw",
                        "leaf bytes snapshotted").inc(raw_total)
            obs.counter("checkpoint.save_bytes_written",
                        "data.bin bytes written").inc(data_bytes)
            obs.histogram("checkpoint.save_seconds",
                          help="checkpoint write latency").observe(
                time.perf_counter() - t0)
        return final

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _cleanup(ckpt_dir: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def _read_block(f, offset: int, size: int, path: str) -> bytes:
    """One positioned block read with transient-failure retries
    (io_point: checkpoint.read)."""
    def attempt():
        io_point("checkpoint.read")
        f.seek(offset)
        return f.read(size)
    data = _retry.call(attempt, policy=_IO_RETRY)
    if len(data) != size:
        raise CheckpointError(f"truncated block in {path}", cause="truncated")
    return data


def _salvage_leaf(eng, e: dict, payloads, raws) -> tuple[bytes, list[int]]:
    """Per-block decode of one leaf, zero-filling failures.

    Chunk i of a leaf covers raw bytes [i*MAX_BLOCK, min((i+1)*MAX_BLOCK,
    raw_size)) — the save-side `_compress_leaf` split — so a failed block
    zero-fills exactly its span.  Returns (raw bytes, failed block indices).
    """
    raw_size = e["raw_size"]
    parts, failed = [], []
    for i, (p, r) in enumerate(zip(payloads, raws)):
        span = min(MAX_BLOCK, raw_size - i * MAX_BLOCK) if raw_size else 0
        try:
            parts.append(eng.decode_blocks([p], [r], usizes=[span])[0])
        except LZ4FormatError:
            parts.append(b"\x00" * span)
            failed.append(i)
    return b"".join(parts)[:raw_size], failed


def restore(ckpt_dir: str, step: int, like, shardings=None,
            decode_engine=None, on_error: str = "raise",
            report: dict | None = None):
    """Rebuild the tree of `like` (a pytree of arrays or ShapeDtypeStructs).

    `shardings`: optional matching pytree of NamedShardings for elastic
    restore onto the current mesh.
    `decode_engine`: optional `LZ4DecodeEngine` override — e.g. an
    ``executor="process"`` engine for multi-core restores, or
    ``executor="device"`` to run block decompression inside the jit graph
    (plan on host, execute on accelerator) instead of in host NumPy.
    `on_error`: ``"raise"`` (default) fails the whole restore on the first
    corrupt block — the strict contract.  ``"salvage"`` decodes every
    undamaged block, ZERO-FILLS the spans of blocks that fail (so the
    restored tree keeps its shapes), and records the damage in `report`
    (``report["zero_filled"]``: leaf path -> failed block indices;
    ``report["crc_mismatch"]``: leaf paths whose whole-leaf checksum did
    not verify) plus the ``resilience.*`` obs counters — never silently.
    A structurally unreadable checkpoint (missing manifest, torn data.bin)
    still raises; `restore_with_fallback` handles stepping back.
    """
    if on_error not in ("raise", "salvage"):
        raise ValueError('on_error must be "raise" or "salvage"')
    t0 = time.perf_counter()
    eng = decode_engine or default_decode_engine()
    final = os.path.join(ckpt_dir, f"ckpt_{step}")
    man_path = os.path.join(final, "manifest.json")
    if not os.path.exists(man_path):
        raise CheckpointError(f"missing manifest: {man_path}",
                              cause="structure")
    with _open_retrying(man_path, "r") as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    data_path = os.path.join(final, "data.bin")
    # Artifact digests (writers since the crash-consistency era): a torn or
    # stale data.bin is rejected before any block is decoded.
    if "data_size" in manifest:
        actual = os.path.getsize(data_path)
        if actual != manifest["data_size"]:
            raise CheckpointError(
                f"data.bin is {actual} bytes, manifest says "
                f"{manifest['data_size']}", cause="truncated")
    if report is not None:
        report.setdefault("zero_filled", {})
        report.setdefault("crc_mismatch", [])
    out_leaves = {}
    raw_total = 0
    with obs.span("checkpoint.restore", step=step), \
            _open_retrying(data_path, "rb") as f:
        for path, spec in _flatten(like):
            if path not in by_path:
                raise CheckpointError(f"leaf {path} not in checkpoint",
                                      cause="structure")
            e = by_path[path]
            payloads, raws = [], []
            for b in e["blocks"]:
                payloads.append(_read_block(f, b["offset"], b["size"], path))
                raws.append(not b["lz4"])
            # Stored-bytes digest: distinguishes media damage (the bytes on
            # disk changed) from a writer bug, before any decode runs.
            if e.get("comp_crc32") is not None and on_error == "raise":
                comp = 0
                for p in payloads:
                    comp = binascii.crc32(p, comp)
                if comp & 0xFFFFFFFF != e["comp_crc32"]:
                    raise CheckpointError(
                        f"stored bytes of {path} failed their digest",
                        cause="crc")
            # A leaf's blocks are independent: the decode engine plans and
            # executes them across its worker pool (or, with the device
            # executor, inside vmapped jit dispatches) instead of a loop.
            failed: list[int] = []
            if on_error == "salvage":
                raw, failed = _salvage_leaf(eng, e, payloads, raws)
                if failed and report is not None:
                    report["zero_filled"][path] = failed
            else:
                try:
                    raw = b"".join(eng.decode_blocks(payloads, raws))
                except LZ4FormatError as err:
                    raise CheckpointError(f"corrupt block in {path}: {err}") from err
            with obs.span("decode.verify", leaf=path):
                if binascii.crc32(bytes(raw)) & 0xFFFFFFFF != e["crc32"]:
                    if on_error == "raise":
                        raise CheckpointError(f"checksum mismatch for {path}",
                                              cause="crc")
                    if report is not None:
                        report["crc_mismatch"].append(path)
            if failed and obs.is_enabled():
                obs.counter("resilience.lost_blocks",
                            "blocks salvage could not recover").inc(len(failed))
            raw_total += len(raw)
            arr = np.frombuffer(bytes(raw), dtype=np.dtype(e["dtype"])).reshape(e["shape"])
            out_leaves[path] = arr
    if obs.is_enabled():
        obs.counter("checkpoint.restores", "checkpoints restored").inc()
        obs.counter("checkpoint.restore_bytes_raw",
                    "leaf bytes restored").inc(raw_total)
        obs.histogram("checkpoint.restore_seconds",
                      help="checkpoint restore latency").observe(
            time.perf_counter() - t0)

    def rebuild(tree, path=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{path}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{path}/{i}") for i, v in enumerate(tree))
        if tree is None:
            return None
        return out_leaves[path]

    host_tree = rebuild(like)
    if shardings is not None:
        host_tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            host_tree, shardings,
        )
    else:
        host_tree = jax.tree.map(jax.device_put, host_tree)
    return host_tree, manifest["step"]


def restore_with_fallback(ckpt_dir: str, like, shardings=None,
                          decode_engine=None, max_steps_back: int | None = None):
    """Restore the NEWEST valid checkpoint, stepping back past corrupt ones.

    The automated form of "corrupt checkpoints raise and the driver falls
    back": walks the directory's steps newest-first, strict-restoring each
    until one verifies end to end.  Corrupt or torn steps are skipped (and
    counted: ``checkpoint.fallback_steps``), never deleted — they stay on
    disk for post-mortem salvage.  ``max_steps_back`` bounds the walk
    (None: try every step present).  Raises `CheckpointError` when no step
    restores.  Returns ``(tree, step)`` like `restore`.
    """
    if not os.path.isdir(ckpt_dir):
        raise CheckpointError(f"no checkpoint directory: {ckpt_dir}",
                              cause="structure")
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("ckpt_") and not d.endswith(".tmp")),
                   reverse=True)
    if max_steps_back is not None:
        steps = steps[: max_steps_back + 1]
    if not steps:
        raise CheckpointError(f"no checkpoints in {ckpt_dir}",
                              cause="structure")
    errors: list[str] = []
    for n, step in enumerate(steps):
        try:
            tree, got = restore(ckpt_dir, step, like, shardings=shardings,
                                decode_engine=decode_engine)
        except (CheckpointError, OSError, ValueError, KeyError) as e:
            errors.append(f"step {step}: {e}")
            if obs.is_enabled():
                obs.counter("checkpoint.fallback_steps",
                            "corrupt checkpoint steps skipped by "
                            "restore_with_fallback").inc()
            continue
        if n and obs.is_enabled():
            obs.counter("checkpoint.fallback_restores",
                        "restores that landed on an older step").inc()
        return tree, got
    raise CheckpointError(
        "no valid checkpoint found; tried "
        + "; ".join(errors), cause="structure")
