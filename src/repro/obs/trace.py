"""Span tracer: nested, thread-aware timing with Chrome-trace export.

The write model is built for hot paths:

  * `span("compress.dispatch", blocks=8)` is a context manager; enter/exit
    take `perf_counter_ns` stamps and push/pop a THREAD-LOCAL span stack,
    so nesting depth and parentage are tracked per thread with no locking
    on the hot path;
  * finished spans append one tuple to a per-thread buffer (buffers are
    registered once, under a lock, on a thread's first span) — concurrent
    threads never contend;
  * when tracing is disabled the module-level `span()` returns a shared
    no-op context manager: the disabled cost is one flag test + one
    attribute call (budgeted by `tests/test_obs.py`'s overhead guard).

Exports:

  * `Tracer.chrome_trace()` — Chrome trace-event JSON (`ph: "X"` complete
    events, microsecond timestamps) that chrome://tracing and Perfetto
    (https://ui.perfetto.dev) load directly;
  * `Tracer.jsonl_events()` — one JSON object per finished span (name,
    thread, start_ns, dur_ns, depth, parent, args), the grep-able log.

Optional bridge: `configure(jax_annotations=True)` (or env
``REPRO_OBS_JAX=1``) wraps every span in `jax.profiler.TraceAnnotation`,
so the same span names show up inside XLA device traces on real hardware
and host spans can be lined up against device timelines.  Lazy import —
the tracer itself never requires jax.

See docs/observability.md for the span catalog and Perfetto how-to.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Span", "Tracer", "NOOP_SPAN"]


class _NoopSpan:
    """Shared do-nothing span (returned whenever tracing is off)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One live timed section.  Use via `Tracer.span` / `repro.obs.span`."""

    __slots__ = ("tracer", "name", "args", "depth", "parent",
                 "start_ns", "_jax_ctx")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.depth = 0
        self.parent: str | None = None
        self.start_ns = 0
        self._jax_ctx = None

    def set(self, **args) -> "Span":
        """Attach/overwrite args (visible in both export formats)."""
        if self.args is None:
            self.args = args
        else:
            self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        if stack:
            top = stack[-1]
            self.depth = top.depth + 1
            self.parent = top.name
        stack.append(self)
        ann = self.tracer._annotation_cls()
        if ann is not None:
            self._jax_ctx = ann(self.name)
            self._jax_ctx.__enter__()
        self.start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        end_ns = time.perf_counter_ns()
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:            # tolerate misnested exits
            stack.remove(self)
        self.tracer._record(self, end_ns)
        return False


class Tracer:
    """Collects finished spans; one instance is the process-wide default.

    ``max_events`` bounds memory: past it new spans are counted in
    ``dropped`` instead of stored (the artifact records the drop count, so
    a truncated trace is never mistaken for a complete one).
    """

    def __init__(self, max_events: int = 500_000):
        self.max_events = max_events
        self.dropped = 0
        self.origin_ns = time.perf_counter_ns()
        self._local = threading.local()
        self._lock = threading.Lock()
        self._buffers: list[tuple[int, str, list]] = []  # (tid, name, events)
        self._jax_annotations = False
        self._ann_cls = None
        self._n_events = 0

    # -- configuration ------------------------------------------------------

    def set_jax_annotations(self, on: bool) -> None:
        self._jax_annotations = bool(on)
        if not on:
            self._ann_cls = None

    def _annotation_cls(self):
        """jax.profiler.TraceAnnotation when bridging is on, else None."""
        if not self._jax_annotations:
            return None
        if self._ann_cls is None:
            try:
                from jax.profiler import TraceAnnotation
            except Exception:           # jax absent/old: bridge silently off
                self._jax_annotations = False
                return None
            self._ann_cls = TraceAnnotation
        return self._ann_cls

    # -- hot path -----------------------------------------------------------

    def _stack(self) -> list:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _events(self) -> list:
        ev = getattr(self._local, "events", None)
        if ev is None:
            ev = self._local.events = []
            t = threading.current_thread()
            with self._lock:
                self._buffers.append((t.ident or 0, t.name, ev))
        return ev

    def span(self, name: str, **args) -> Span:
        return Span(self, name, args or None)

    def _record(self, span: Span, end_ns: int) -> None:
        if self._n_events >= self.max_events:
            self.dropped += 1
            return
        self._n_events += 1  # benign race: the cap is a bound, not a ledger
        self._events().append(
            (span.name, span.start_ns, end_ns - span.start_ns,
             span.depth, span.parent, span.args)
        )

    # -- export -------------------------------------------------------------

    def finished(self) -> list[dict]:
        """All finished spans as dicts, ordered by start time."""
        with self._lock:
            bufs = [(tid, name, list(ev)) for tid, name, ev in self._buffers]
        rows = []
        for tid, tname, events in bufs:
            for name, start, dur, depth, parent, args in events:
                rows.append({
                    "name": name, "tid": tid, "thread": tname,
                    "start_ns": start - self.origin_ns, "dur_ns": dur,
                    "depth": depth, "parent": parent,
                    "args": args or {},
                })
        rows.sort(key=lambda r: r["start_ns"])
        return rows

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (load in Perfetto as-is)."""
        pid = os.getpid()
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro-lz4"},
        }]
        with self._lock:
            bufs = [(tid, name, list(ev)) for tid, name, ev in self._buffers]
        for tid, tname, buf in bufs:
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": tname},
            })
            for name, start, dur, depth, parent, args in buf:
                ev = {
                    "name": name, "cat": "repro", "ph": "X", "pid": pid,
                    "tid": tid,
                    "ts": (start - self.origin_ns) / 1e3,   # microseconds
                    "dur": dur / 1e3,
                }
                if args:
                    ev["args"] = {k: _jsonable(v) for k, v in args.items()}
                events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def jsonl_events(self) -> str:
        """One JSON object per finished span, newline-delimited."""
        return "".join(
            json.dumps(
                {**r, "args": {k: _jsonable(v) for k, v in r["args"].items()}},
                sort_keys=True) + "\n"
            for r in self.finished()
        )

    def reset(self) -> None:
        """Drop recorded spans (thread-local stacks of LIVE spans survive)."""
        with self._lock:
            for _, _, ev in self._buffers:
                ev.clear()
            self._n_events = 0
            self.dropped = 0
            self.origin_ns = time.perf_counter_ns()


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
