"""`repro.obs` — unified telemetry: span tracing + metrics, off by default.

One import point for every instrumented site in the repo:

    from repro import obs

    with obs.span("compress.dispatch", blocks=8):
        ...
    obs.counter("engine.bytes_in").inc(n)
    obs.histogram("engine.block_ratio", obs.DEFAULT_RATIO_BUCKETS).observe(r)

Gating
------
Telemetry is OFF unless the ``REPRO_OBS`` env var is truthy (anything but
``""``/``"0"``/``"false"``/``"off"``) or `obs.configure(enabled=True)` ran.
Disabled, `span()` hands back a shared no-op context manager and
`counter/gauge/histogram` hand back a shared no-op instrument — the cost
is one flag test per call site, budgeted at < 2 % of a compress microloop
by `tests/test_obs.py`.  The engines additionally accept a ``telemetry``
kwarg (True/False/None) that overrides the global flag per instance.

``REPRO_OBS_JAX=1`` (or `configure(jax_annotations=True)`) additionally
wraps every span in `jax.profiler.TraceAnnotation`, so span names line up
with XLA device traces on real hardware.

Artifacts
---------
`obs.dump_artifacts(dir)` writes the full bundle:

    trace.json     Chrome trace-event JSON  (load at https://ui.perfetto.dev)
    events.jsonl   one JSON object per span (grep-able log)
    metrics.json   registry snapshot (counters/gauges/histograms + p50/90/99)
    metrics.prom   Prometheus text exposition

`tools/trace_report.py <dir>` prints the per-stage breakdown table from a
bundle and `--check` schema-validates it (CI runs both).  Full API and
span catalog: docs/observability.md.
"""
from __future__ import annotations

import json
import os

from .metrics import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS,
    DEFAULT_RATIO_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exponential_buckets,
    linear_buckets,
)
from .trace import NOOP_SPAN, Span, Tracer  # noqa: F401

__all__ = [
    "configure", "is_enabled", "enabled_for",
    "span", "live_span", "span_factory",
    "counter", "gauge", "histogram", "registry", "tracer",
    "snapshot", "dump_artifacts", "reset",
    "NOOP_SPAN", "NOOP_METRIC", "Span", "Tracer", "MetricsRegistry",
    "Counter", "Gauge", "Histogram",
    "DEFAULT_LATENCY_BUCKETS", "DEFAULT_RATIO_BUCKETS",
    "exponential_buckets", "linear_buckets",
]

ARTIFACT_SCHEMA_VERSION = 1


def _env_truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in (
        "", "0", "false", "off", "no")


_ENABLED = _env_truthy("REPRO_OBS")
_TRACER = Tracer()
_REGISTRY = MetricsRegistry()
if _env_truthy("REPRO_OBS_JAX"):
    _TRACER.set_jax_annotations(True)


class _NoopMetric:
    """Counter/Gauge/Histogram stand-in when telemetry is off."""

    __slots__ = ()

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


_NOOP_METRIC = NOOP_METRIC = _NoopMetric()


def configure(enabled: bool | None = None,
              jax_annotations: bool | None = None) -> None:
    """Runtime override of the env-var gates (tests, notebooks, drivers)."""
    global _ENABLED
    if enabled is not None:
        _ENABLED = bool(enabled)
    if jax_annotations is not None:
        _TRACER.set_jax_annotations(jax_annotations)


def is_enabled() -> bool:
    return _ENABLED


def enabled_for(override: bool | None) -> bool:
    """Resolve a per-instance ``telemetry`` kwarg against the global flag."""
    return _ENABLED if override is None else bool(override)


def tracer() -> Tracer:
    return _TRACER


def registry() -> MetricsRegistry:
    return _REGISTRY


# -- hot-path entry points --------------------------------------------------

def span(name: str, **args):
    """Timed context manager; a shared no-op when telemetry is off."""
    if not _ENABLED:
        return NOOP_SPAN
    return Span(_TRACER, name, args or None)


def live_span(name: str, **args) -> Span:
    """A recording span regardless of the global flag (engine ``telemetry=
    True`` instances use this so a single engine can be traced without
    turning the whole process on)."""
    return Span(_TRACER, name, args or None)


def span_factory(enabled: bool):
    """`live_span` or the no-op maker, picked once per engine call."""
    return live_span if enabled else _noop_span


def _noop_span(name: str, **args):
    return NOOP_SPAN


def counter(name: str, help: str = ""):
    return _REGISTRY.counter(name, help) if _ENABLED else _NOOP_METRIC


def gauge(name: str, help: str = ""):
    return _REGISTRY.gauge(name, help) if _ENABLED else _NOOP_METRIC


def histogram(name: str, buckets=DEFAULT_LATENCY_BUCKETS, help: str = ""):
    return _REGISTRY.histogram(name, buckets, help) if _ENABLED \
        else _NOOP_METRIC


# -- snapshots / artifacts --------------------------------------------------

def snapshot() -> dict:
    """Registry snapshot wrapped with the artifact schema header."""
    return {
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "enabled": _ENABLED,
        "metrics": _REGISTRY.snapshot(),
    }


def dump_artifacts(out_dir: str) -> dict:
    """Write trace.json / events.jsonl / metrics.json / metrics.prom.

    Returns ``{name: path}`` for the four files.  The directory is created;
    existing artifacts are overwritten (a dump is a point-in-time export —
    recording continues afterwards; call `reset()` to start a fresh
    window).
    """
    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    trace_path = os.path.join(out_dir, "trace.json")
    with open(trace_path, "w") as f:
        json.dump(_TRACER.chrome_trace(), f)
    paths["trace"] = trace_path
    jsonl_path = os.path.join(out_dir, "events.jsonl")
    with open(jsonl_path, "w") as f:
        f.write(_TRACER.jsonl_events())
    paths["events"] = jsonl_path
    metrics_path = os.path.join(out_dir, "metrics.json")
    with open(metrics_path, "w") as f:
        json.dump(snapshot(), f, indent=1)
    paths["metrics"] = metrics_path
    prom_path = os.path.join(out_dir, "metrics.prom")
    with open(prom_path, "w") as f:
        f.write(_REGISTRY.to_prometheus())
    paths["prometheus"] = prom_path
    return paths


def reset() -> None:
    """Clear recorded spans and all metrics (tests; fresh windows)."""
    _TRACER.reset()
    _REGISTRY.reset()
