"""Metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only) so the telemetry layer can ship with the
core library and never gates on an optional package.  Three instrument
types, one process-wide registry (plus private registries for tests):

  * `Counter`   — monotone accumulator (`inc`), e.g. bytes compressed;
  * `Gauge`     — last-value instrument (`set`/`inc`), e.g. in-flight
                  micro-batches in the engine's double buffer;
  * `Histogram` — fixed upper-bound buckets with a running sum/count and
                  interpolated quantile estimates (`quantile(0.99)`), e.g.
                  per-block compression ratio or dispatch latency.

Exporters:

  * `MetricsRegistry.snapshot()`      — plain-dict JSON form (the machine
                                        interface `tools/trace_report.py`
                                        consumes);
  * `MetricsRegistry.to_prometheus()` — Prometheus text exposition format
                                        (metric names sanitized `a.b` ->
                                        `a_b`; histograms emit the
                                        cumulative `_bucket`/`_sum`/`_count`
                                        series).

All instruments are thread-safe: one lock per instrument (registration
itself takes the registry lock).  Quantiles are estimates — linear
interpolation inside the covering bucket — with worst-case error of one
bucket width; pick buckets accordingly (`exponential_buckets` /
`linear_buckets`).  See docs/observability.md.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "exponential_buckets",
    "linear_buckets",
    "DEFAULT_LATENCY_BUCKETS",
    "DEFAULT_RATIO_BUCKETS",
]


def exponential_buckets(start: float, factor: float, count: int) -> tuple:
    """`count` upper bounds: start, start*factor, ... (Prometheus idiom)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


def linear_buckets(start: float, width: float, count: int) -> tuple:
    if width <= 0 or count < 1:
        raise ValueError("need width > 0, count >= 1")
    return tuple(start + width * i for i in range(count))


# Seconds-scale latency: 1 us .. ~67 s, factor 2 (worst-case quantile
# error = one octave; plenty for per-stage breakdowns).
DEFAULT_LATENCY_BUCKETS = exponential_buckets(1e-6, 2.0, 26)
# Compression ratio (usize/csize): 0.25 .. 16, factor 2^(1/2).
DEFAULT_RATIO_BUCKETS = exponential_buckets(0.25, math.sqrt(2.0), 12)


class Counter:
    """Monotone counter.  `inc(n)` with n >= 0."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int | float:
        return self._value


class Gauge:
    """Last-value instrument (`set`), with `inc`/`dec` for occupancy."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0
        self._lock = threading.Lock()

    def set(self, v: int | float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: int | float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> int | float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated quantile estimates.

    ``buckets`` are sorted upper bounds; an implicit +Inf bucket catches
    the overflow.  `quantile(q)` walks the cumulative counts to the
    covering bucket and interpolates linearly between its bounds (the
    overflow bucket reports the largest finite bound — quantiles cannot
    resolve past the configured range).
    """

    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_count",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, buckets=DEFAULT_LATENCY_BUCKETS,
                 help: str = ""):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError("buckets must be non-empty and increasing")
        self.name = name
        self.help = help
        self.buckets = bounds
        self._counts = [0] * (len(bounds) + 1)  # +Inf overflow at the end
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0 <= q <= 1); nan when empty."""
        if not 0 <= q <= 1:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            total = self._count
            if total == 0:
                return math.nan
            rank = q * total
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= rank and c:
                    if i >= len(self.buckets):      # overflow bucket
                        return self._max if math.isfinite(self._max) \
                            else self.buckets[-1]
                    hi = self.buckets[i]
                    lo = self.buckets[i - 1] if i else min(self._min, hi)
                    lo = max(lo, 0.0) if self._min >= 0 else lo
                    frac = (rank - (cum - c)) / c
                    return lo + (hi - lo) * frac
            return self._max

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
            mn = self._min if self._count else None
            mx = self._max if self._count else None
        snap = {
            "count": total,
            "sum": s,
            "min": mn,
            "max": mx,
            "buckets": [[b, c] for b, c in zip(self.buckets, counts)]
            + [["+Inf", counts[-1]]],
        }
        for q in (0.5, 0.9, 0.99):
            v = self.quantile(q)
            snap[f"p{int(q * 100)}"] = None if math.isnan(v) else v
        return snap


def _prom_name(name: str) -> str:
    """Sanitize a dotted metric name into a Prometheus identifier."""
    out = "".join(c if (c.isalnum() or c == "_") else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors and exporters."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, buckets=DEFAULT_LATENCY_BUCKETS,
                  help: str = "") -> Histogram:
        return self._get(name, Histogram, buckets, help)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready snapshot (the `metrics.json` artifact payload)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.snapshot()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            pn = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pn} counter")
                lines.append(f"{pn} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pn} gauge")
                lines.append(f"{pn} {m.value}")
            else:
                lines.append(f"# TYPE {pn} histogram")
                snap = m.snapshot()
                cum = 0
                for le, c in snap["buckets"]:
                    cum += c
                    le_s = "+Inf" if le == "+Inf" else repr(float(le))
                    lines.append(f'{pn}_bucket{{le="{le_s}"}} {cum}')
                lines.append(f"{pn}_sum {snap['sum']}")
                lines.append(f"{pn}_count {snap['count']}")
        return "\n".join(lines) + "\n"
