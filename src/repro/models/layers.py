"""Shared neural building blocks (pure functional, dict params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def norm_init(d, layer_norm: bool, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if layer_norm:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, eps: float, layer_norm: bool):
    xf = x.astype(jnp.float32)
    if layer_norm:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf**2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def linear_init(key, d_in, d_out, use_bias=False, dtype=jnp.float32):
    p = {"w": _init(key, (d_in, d_out), dtype=dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def mlp_init(key, d, f, use_bias=False, gated=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if gated:
        return {
            "w_gate": linear_init(ks[0], d, f, use_bias, dtype),
            "w_up": linear_init(ks[1], d, f, use_bias, dtype),
            "w_down": linear_init(ks[2], f, d, use_bias, dtype),
        }
    return {
        "w_in": linear_init(ks[0], d, f, use_bias, dtype),
        "w_out": linear_init(ks[1], f, d, use_bias, dtype),
    }


def apply_mlp(p, x):
    if "w_gate" in p:
        return apply_linear(
            p["w_down"], jax.nn.silu(apply_linear(p["w_gate"], x)) * apply_linear(p["w_up"], x)
        )
    return apply_linear(p["w_out"], jax.nn.gelu(apply_linear(p["w_in"], x)))


def rope(x, positions, theta: float):
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int, offset=0):
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    half = d // 2
    freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def cross_entropy_chunked(logits_fn, x, labels, mask, vocab: int, chunk: int = 4096):
    """Mean CE without materializing (B,S,V): map over flattened token chunks.

    logits_fn: (T, d) -> (T, V).  mask: (B,S) float weights.
    """
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    lt = labels.reshape(B * S)
    mt = mask.reshape(B * S)
    T = B * S
    chunk = min(chunk, T)
    n_chunks = T // chunk
    rem = T - n_chunks * chunk

    def one(xc, lc, mc):
        logits = logits_fn(xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return ((logz - gold) * mc).sum()

    def body(carry, args):
        return carry + one(*args), None

    xs = (
        xt[: n_chunks * chunk].reshape(n_chunks, chunk, d),
        lt[: n_chunks * chunk].reshape(n_chunks, chunk),
        mt[: n_chunks * chunk].reshape(n_chunks, chunk),
    )
    total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
    if rem:
        total = total + one(xt[-rem:], lt[-rem:], mt[-rem:])
    return total / jnp.maximum(mt.sum(), 1.0)
