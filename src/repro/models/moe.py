"""Mixtral-style MoE FFN (8 experts, top-2) with shard_map expert compute.

Communication pattern (mapped onto jax-native constructs, not NCCL-emulated):
  * tokens stay local to their DP shard — dispatch is a per-device sort
    (stable argsort by expert id + capacity clamp), so there is NO cross-
    device token exchange;
  * expert hidden dim is TP-sharded on "model" -> one psum per layer (same
    collective as a dense TP FFN);
  * with FSDP, expert weights are additionally sharded on "data" and
    all-gathered on use (XLA turns the gradient into a reduce-scatter).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    batch_axes,
    get_mesh,
    shard_map_compat as _shard_map_compat,
)
from .layers import _init


def moe_init(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "wr": {"w": _init(ks[0], (d, e), dtype=dtype)},
        "w1": {"w": _init(ks[1], (e, d, f), dtype=dtype)},
        "w3": {"w": _init(ks[2], (e, d, f), dtype=dtype)},
        "w2": {"w": _init(ks[3], (e, f, d), scale=1.0 / (f**0.5), dtype=dtype)},
    }


def _local_moe(x, wr, w1, w3, w2, cfg, fsdp: bool, tp: bool = True):
    """Per-DP-shard expert compute. x: (B_loc, S, d) local shard."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = max(8, int(T * k / E * cfg.capacity_factor))  # static capacity

    if fsdp:
        gax = "data" if tp else ("data", "model")
        w1 = jax.lax.all_gather(w1, gax, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, gax, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, gax, axis=2, tiled=True)

    t = x.reshape(T, d)
    logits = (t.astype(jnp.float32) @ wr.astype(jnp.float32))  # (T, E)
    top_vals, top_idx = jax.lax.top_k(logits, k)               # (T, k)
    gates = jax.nn.softmax(top_vals, axis=-1)                  # mixtral renorm

    # --- sort-based dispatch (per device) ---
    flat_e = top_idx.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - jnp.searchsorted(
        sorted_e, sorted_e, side="left"
    ).astype(jnp.int32)
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)      # OOB -> dropped
    tok = order // k
    buf = (
        jnp.zeros((E * C, d), x.dtype)
        .at[slot]
        .add(t[tok] * keep[:, None].astype(x.dtype), mode="drop")
    )
    be = buf.reshape(E, C, d)

    # --- expert FFN (hidden dim TP-sharded; dims here are the local F/TP) ---
    h = jnp.einsum("ecd,edf->ecf", be, w1.astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", be, w3.astype(x.dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2.astype(x.dtype))
    if tp:
        o = jax.lax.psum(o, "model")                             # TP reduce

    # --- combine ---
    slot_by_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.where(keep, slot, -1)
    )
    ok = slot_by_flat >= 0
    gathered = jnp.take(o.reshape(E * C, d), jnp.clip(slot_by_flat, 0), axis=0)
    gathered = gathered * ok[:, None].astype(x.dtype)
    y = (gathered.reshape(T, k, d) * gates[..., None].astype(x.dtype)).sum(1)

    # load-balancing aux loss (GShard): E * sum_e fraction_e * prob_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    aux = E * jnp.sum(frac * probs.mean(0))
    return y.reshape(B, S, d), aux


def _dense_moe(p, x, cfg):
    """All-experts einsum path for tiny/non-DP-divisible token counts (decode
    with small batch): identical function value when no capacity drops occur."""
    E, k = cfg.n_experts, cfg.top_k
    w1, w3, w2 = p["w1"]["w"], p["w3"]["w"], p["w2"]["w"]
    logits = (x.astype(jnp.float32) @ p["wr"]["w"].astype(jnp.float32))  # (B,S,E)
    top_vals, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    gate_full = jnp.zeros(logits.shape, jnp.float32)
    for i in range(k):
        gate_full = gate_full + jax.nn.one_hot(top_idx[..., i], E) * gates[..., i : i + 1]
    h = jnp.einsum("bsd,edf->bsef", x, w1.astype(x.dtype))
    g = jnp.einsum("bsd,edf->bsef", x, w3.astype(x.dtype))
    o = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * g, w2.astype(x.dtype))
    y = jnp.einsum("bsed,bse->bsd", o, gate_full.astype(x.dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jax.nn.one_hot(top_idx, E).sum((0, 1, 2)) / (logits.shape[0] * logits.shape[1] * k)
    aux = E * jnp.sum(frac * probs.mean((0, 1)))
    return y, aux


def moe_ffn(p, x, cfg):
    """x: (B, S, d) global. Returns (y, aux_loss)."""
    import numpy as np

    mesh = get_mesh()
    ba = batch_axes(mesh, cfg.pure_dp)
    n_dp = int(np.prod([mesh.shape[a] for a in ba])) if (mesh and ba) else 1
    if x.shape[0] % n_dp != 0:
        return _dense_moe(p, x, cfg)
    fsdp = cfg.fsdp and mesh is not None and mesh.shape.get("data", 1) > 1
    tp = not cfg.pure_dp
    fax = ("data", "model") if (fsdp and not tp) else ("data" if fsdp else None)
    wspec_df = P(None, fax, "model" if tp else None)
    wspec_fd = P(None, "model" if tp else None, fax)

    def wrapped(xx, wr, w1, w3, w2):
        y, aux = _local_moe(xx, wr, w1, w3, w2, cfg, fsdp, tp)
        if ba:
            aux = jax.lax.pmean(aux, ba)
        return y, aux

    fn = _shard_map_compat()(
        wrapped,
        mesh=mesh,
        in_specs=(
            P(ba, None, None),
            P(None, None),
            wspec_df,
            wspec_df,
            wspec_fd,
        ),
        out_specs=(P(ba, None, None), P()),
        check_vma=False,
    )
    y, aux = fn(x, p["wr"]["w"], p["w1"]["w"], p["w3"]["w"], p["w2"]["w"])
    return y, aux
