"""GQA attention: chunked (train/prefill), cached single-token (decode).

Memory strategy: python-loop over query chunks with *exact* static KV slices
(causal: [0:(i+1)C], local: a window+chunk wide band) — no masked-out compute
beyond intra-chunk triangles, each chunk wrapped in jax.checkpoint.  KV heads
are broadcast to the query heads (GQA repeat) so the only sharded head axis is
n_q, which GSPMD pads when n_heads % TP != 0 (whisper 12, minicpm 36).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .layers import apply_norm, linear_init, rope, softcap

Q_CHUNK = 512


def attention_init(key, cfg, d_kv_src=None, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.hd
    d_kv_src = d_kv_src or d
    ks = jax.random.split(key, 6)
    p = {
        "wq": linear_init(ks[0], d, cfg.n_heads * hd, cfg.use_bias, dtype),
        "wk": linear_init(ks[1], d_kv_src, cfg.n_kv_heads * hd, cfg.use_bias, dtype),
        "wv": linear_init(ks[2], d_kv_src, cfg.n_kv_heads * hd, cfg.use_bias, dtype),
        "wo": linear_init(ks[3], cfg.n_heads * hd, d, cfg.use_bias, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


def _project_qkv(p, x, kv_src, cfg, q_positions, kv_positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]["w"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]["w"].astype(x.dtype)).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, hd
    )
    v = (kv_src @ p["wv"]["w"].astype(x.dtype)).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, hd
    )
    if cfg.use_bias:
        q = q + p["wq"]["b"].reshape(cfg.n_heads, hd).astype(x.dtype)
        k = k + p["wk"]["b"].reshape(cfg.n_kv_heads, hd).astype(x.dtype)
        v = v + p["wv"]["b"].reshape(cfg.n_kv_heads, hd).astype(x.dtype)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, cfg.norm_eps, False)
        k = apply_norm(p["k_norm"], k, cfg.norm_eps, False)
    if cfg.pos_type == "rope" and q_positions is not None:
        q = rope(q, q_positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd); mask: (Sq,Sk) bool or None."""
    scale = cfg.hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _repeat_kv(k, n_heads):
    g = n_heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def multihead_attention(
    p,
    x,
    cfg,
    attn_type: str = "global",
    memory=None,
    positions=None,
):
    """Training/prefill attention. Returns (out, (k, v)) for cache fill.

    attn_type: "global" (causal), "local" (causal sliding window), "bidir".
    memory: (B, S_mem, d) for cross attention (bidir over memory).
    """
    B, S, d = x.shape
    kv_src = memory if memory is not None else x
    S_kv = kv_src.shape[1]
    q_pos = positions if positions is not None else jnp.arange(S)[None, :]
    kv_pos = None if memory is not None else q_pos
    q, k, v = _project_qkv(p, x, kv_src, cfg, q_pos if memory is None else q_pos, kv_pos)
    kf = _repeat_kv(k, cfg.n_heads)
    vf = _repeat_kv(v, cfg.n_heads)

    if memory is not None or attn_type == "bidir":
        if S <= Q_CHUNK * 2 and S_kv <= 4096:
            out = _sdpa(q, kf, vf, None, cfg)
        else:
            outs = []
            for i in range(0, S, Q_CHUNK):
                qc = q[:, i : i + Q_CHUNK]
                outs.append(jax.checkpoint(_sdpa, static_argnums=(4,))(qc, kf, vf, None, cfg))
            out = jnp.concatenate(outs, axis=1)
    else:
        out = _causal_chunked(q, kf, vf, cfg, local=(attn_type == "local"))

    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    y = out @ p["wo"]["w"].astype(x.dtype)
    if cfg.use_bias:
        y = y + p["wo"]["b"].astype(x.dtype)
    return y, (k, v)


def _causal_chunked(q, k, v, cfg, local: bool):
    """Causal (optionally sliding-window) attention with exact KV slices."""
    B, S, H, hd = q.shape
    C = min(Q_CHUNK, S)
    assert S % C == 0, (S, C)
    window = cfg.window
    pos = jnp.arange(S)

    def chunk_attn(qc, kc, vc, q0, k0, kw):
        qp = q0 + jnp.arange(qc.shape[1])
        kp = k0 + jnp.arange(kw)
        mask = kp[None, :] <= qp[:, None]
        if local:
            mask &= kp[None, :] > qp[:, None] - window
        return _sdpa(qc, kc, vc, mask, cfg)

    outs = []
    for i in range(0, S, C):
        if local:
            k0 = max(0, i + C - (window + C))
            kw = i + C - k0
        else:
            k0 = 0
            kw = i + C
        qc = q[:, i : i + C]
        kc = k[:, k0 : k0 + kw]
        vc = v[:, k0 : k0 + kw]
        outs.append(
            jax.checkpoint(chunk_attn, static_argnums=(3, 4, 5))(qc, kc, vc, i, k0, kw)
        )
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Decode path: single new token against a KV cache.
# ---------------------------------------------------------------------------

def make_cache(cfg, attn_type: str, batch: int, cache_len: int, dtype):
    """Cache for one attention layer."""
    size = min(cfg.window, cache_len) if attn_type == "local" else cache_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),  # absolute position per slot
    }


def fill_cache(cache, k, v, start: int = 0):
    """Prefill: write S tokens (positions start..start+S) into the cache."""
    S = k.shape[1]
    size = cache["k"].shape[1]
    if size >= S:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, start, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, start, axis=1)
        cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.arange(start, start + S, dtype=jnp.int32), start, axis=0
        )
        return cache
    # rolling (local) cache: keep the last `size` tokens
    cache = dict(cache)
    tail_pos = jnp.arange(S - size, S, dtype=jnp.int32) + start
    slots = tail_pos % size
    cache["k"] = cache["k"].at[:, slots].set(k[:, -size:])
    cache["v"] = cache["v"].at[:, slots].set(v[:, -size:])
    cache["pos"] = cache["pos"].at[slots].set(tail_pos)
    return cache


def decode_attention(p, x, cfg, cache, pos, attn_type: str = "global", memory_cache=None):
    """x: (B,1,d); pos: scalar int32 — absolute position of the new token.

    Returns (out (B,1,d), updated cache).
    """
    B = x.shape[0]
    hd = cfg.hd
    q = (x @ p["wq"]["w"].astype(x.dtype)).reshape(B, 1, cfg.n_heads, hd)
    if cfg.use_bias:
        q = q + p["wq"]["b"].reshape(cfg.n_heads, hd).astype(x.dtype)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, cfg.norm_eps, False)
    if cfg.pos_type == "rope":
        q = rope(q, jnp.full((B, 1), pos), cfg.rope_theta)

    if memory_cache is not None:  # cross attention: static precomputed k/v
        kf = _repeat_kv(memory_cache["k"], cfg.n_heads)
        vf = _repeat_kv(memory_cache["v"], cfg.n_heads)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) * hd**-0.5
        scores = softcap(scores, cfg.attn_softcap)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
        y = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]["w"].astype(x.dtype)
        if cfg.use_bias:
            y = y + p["wo"]["b"].astype(x.dtype)
        return y, cache

    k_new = (x @ p["wk"]["w"].astype(x.dtype)).reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = (x @ p["wv"]["w"].astype(x.dtype)).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.use_bias:
        k_new = k_new + p["wk"]["b"].reshape(cfg.n_kv_heads, hd).astype(x.dtype)
        v_new = v_new + p["wv"]["b"].reshape(cfg.n_kv_heads, hd).astype(x.dtype)
    if cfg.qk_norm:
        k_new = apply_norm(p["k_norm"], k_new, cfg.norm_eps, False)
    if cfg.pos_type == "rope":
        k_new = rope(k_new, jnp.full((B, 1), pos), cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = pos % size
    if cfg.cache_update == "masked":
        # per-shard local update: no collectives on a seq-sharded cache
        sel = (jax.lax.iota(jnp.int32, size) == slot)
        k_all = jnp.where(sel[None, :, None, None], k_new.astype(cache["k"].dtype), cache["k"])
        v_all = jnp.where(sel[None, :, None, None], v_new.astype(cache["v"].dtype), cache["v"])
        pos_all = jnp.where(sel, pos, cache["pos"])
    else:
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
        pos_all = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0
        )
    new_cache = {"k": k_all, "v": v_all, "pos": pos_all}

    ax = cfg.decode_cache_axes
    if ax:
        import numpy as _np

        from repro.distributed.sharding import batch_axes, constrain, get_mesh

        mesh = get_mesh()
        ba = batch_axes(mesh)
        if B % (int(_np.prod([mesh.shape[a] for a in ba])) or 1) != 0:
            ba = None
        k_all = constrain(k_all, ba, ax, None, None)
        v_all = constrain(v_all, ba, ax, None, None)
    kf = _repeat_kv(k_all, cfg.n_heads)
    vf = _repeat_kv(v_all, cfg.n_heads)
    if ax:
        kf = constrain(kf, ba, ax, None, None)
        vf = constrain(vf, ba, ax, None, None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) * hd**-0.5
    if ax:  # keep scores sharded along the cache sequence axis
        scores = constrain(scores, ba, None, None, ax)
    scores = softcap(scores, cfg.attn_softcap)
    valid = (pos_all >= 0) & (pos_all <= pos)
    if attn_type == "local":
        valid &= pos_all > pos - cfg.window
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    if ax:
        probs = constrain(probs, ba, None, None, ax)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    y = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]["w"].astype(x.dtype)
    if cfg.use_bias:
        y = y + p["wo"]["b"].astype(x.dtype)
    return y, new_cache
