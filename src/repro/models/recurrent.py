"""Recurrent mixers: RG-LRU (Griffin/RecurrentGemma), mLSTM and sLSTM (xLSTM).

Parallelization strategy per mixer:
  * RG-LRU — diagonal linear recurrence -> jax.lax.associative_scan (log-depth).
  * mLSTM  — matrix-memory recurrence with exponential gating; implemented in
    the chunkwise-parallel form (intra-chunk attention-like matrix + inter-
    chunk state carry, log-space stabilized).  A step form serves decode and
    as the equality oracle (tests assert chunkwise == sequential).
  * sLSTM  — has hidden-to-hidden recurrence (R_z h_{t-1}) and is inherently
    sequential: lax.scan over time.  This is an xLSTM property, not an
    implementation shortcut (see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _init, linear_init, apply_linear

_LRU_C = 8.0  # Griffin's fixed constant on the recurrence gate


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rglru_init(key, cfg, dtype=jnp.float32):
    d, r = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "wx": linear_init(ks[0], d, r, dtype=dtype),
        "wgate": linear_init(ks[1], d, r, dtype=dtype),
        "wa": linear_init(ks[2], r, r, dtype=dtype),
        "wi_gate": linear_init(ks[3], r, r, dtype=dtype),
        "wo_proj": linear_init(ks[4], r, d, dtype=dtype),
        "conv_w": _init(ks[5], (cfg.conv_width, r), scale=0.3, dtype=dtype),
        "lam": jnp.full((r,), 0.65, dtype),  # Lambda param; a ~ exp(-8*softplus(lam)*sig)
    }


def _causal_conv(u, w):
    """Depthwise causal conv over time. u: (B,S,r), w: (W,r)."""
    W = w.shape[0]
    out = u * w[W - 1].astype(u.dtype)
    for j in range(1, W):
        shifted = jnp.pad(u[:, :-j], ((0, 0), (j, 0), (0, 0)))
        out = out + shifted * w[W - 1 - j].astype(u.dtype)
    return out


def _lru_scan(a, bx):
    """h_t = a_t * h_{t-1} + bx_t via associative scan over axis 1."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return bb


def rglru_block(p, x, cfg):
    """Griffin recurrent block. x: (B,S,d) -> (y, final_state)."""
    gate = jax.nn.gelu(apply_linear(p["wgate"], x))
    u_pre = apply_linear(p["wx"], x)
    u = _causal_conv(u_pre, p["conv_w"])
    uf = u.astype(jnp.float32)
    ra = jax.nn.sigmoid(uf @ p["wa"]["w"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * ra
    a = jnp.exp(log_a)
    i = jax.nn.sigmoid(uf @ p["wi_gate"]["w"].astype(jnp.float32))
    bx = jnp.sqrt(jnp.clip(1.0 - a**2, 1e-9)) * (i * uf)
    h = _lru_scan(a, bx)
    W = cfg.conv_width
    conv_tail = jnp.pad(u_pre, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):]
    state = {"h": h[:, -1], "conv": conv_tail}
    y = apply_linear(p["wo_proj"], h.astype(x.dtype) * gate)
    return y, state


def rglru_state_init(cfg, batch: int, dtype):
    r = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    }


def rglru_step(p, x, cfg, state):
    """x: (B,1,d) decode step. Returns (y (B,1,d), state)."""
    gate = jax.nn.gelu(apply_linear(p["wgate"], x))[:, 0]
    u = apply_linear(p["wx"], x)[:, 0]  # (B, r)
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B,W,r)
    w = p["conv_w"].astype(u.dtype)
    u_c = (hist * w[None]).sum(1)
    uf = u_c.astype(jnp.float32)
    ra = jax.nn.sigmoid(uf @ p["wa"]["w"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * ra
    a = jnp.exp(log_a)
    i = jax.nn.sigmoid(uf @ p["wi_gate"]["w"].astype(jnp.float32))
    h = a * state["h"] + jnp.sqrt(jnp.clip(1.0 - a**2, 1e-9)) * (i * uf)
    y = apply_linear(p["wo_proj"], (h.astype(x.dtype) * gate)[:, None])
    return y, {"h": h, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, exponential gating) — chunkwise parallel
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner or 2 * d
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "w_up": linear_init(ks[0], d, di, dtype=dtype),     # value path
        "w_z": linear_init(ks[1], d, di, dtype=dtype),      # output gate path
        "conv_w": _init(ks[2], (cfg.conv_width, di), scale=0.3, dtype=dtype),
        "wq": linear_init(ks[3], di, di, dtype=dtype),
        "wk": linear_init(ks[4], di, di, dtype=dtype),
        "wv": linear_init(ks[5], di, di, dtype=dtype),
        "w_if": linear_init(ks[6], di, 2 * H, dtype=dtype),  # i,f gate logits
        "w_down": linear_init(ks[7], di, d, dtype=dtype),
        "skip": jnp.ones((di,), dtype),
    }


def _mlstm_chunk(q, k, v, i_g, f_g, chunk: int):
    """Chunkwise mLSTM. q,k,v: (B,S,H,p) f32; i_g,f_g: (B,S,H) f32 logits.

    Returns h: (B,S,H,p).  Stabilized in log space; state carried across
    chunks is (C~ (B,H,p,p), n~ (B,H,p), m (B,H)) with true C = C~ e^m.
    """
    B, S, H, p_dim = q.shape
    L = min(chunk, S)
    assert S % L == 0
    N = S // L
    qc = q.reshape(B, N, L, H, p_dim)
    kc = k.reshape(B, N, L, H, p_dim)
    vc = v.reshape(B, N, L, H, p_dim)
    ic = i_g.reshape(B, N, L, H)
    fc = jax.nn.log_sigmoid(f_g).reshape(B, N, L, H)

    def body(carry, xs):
        Ct, nt, mt = carry            # (B,H,p,p), (B,H,p), (B,H)
        qq, kk, vv, ii, ff = xs        # (B,L,H,p), ..., (B,L,H)
        F = jnp.cumsum(ff, axis=1)     # (B,L,H) log decay from chunk start (incl t)
        # intra-chunk log weights: F_t - F_s + i_s for s <= t
        lw = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]  # (B,t,s,H)
        t_idx = jnp.arange(L)
        causal = t_idx[:, None] >= t_idx[None, :]
        lw = jnp.where(causal[None, :, :, None], lw, -jnp.inf)
        a_t = F + mt[:, None, :]                        # initial-state branch
        b_t = lw.max(axis=2)                            # (B,t,H)
        m_new = jnp.maximum(a_t, b_t)
        m_new = jnp.maximum(m_new, -1e30)               # guard -inf
        # intra contribution
        D = jnp.exp(lw - m_new[:, :, None, :])          # (B,t,s,H)
        scores = jnp.einsum("bthp,bshp->btsh", qq, kk) * (p_dim**-0.5)
        num_intra = jnp.einsum("btsh,bshp->bthp", scores * D, vv)
        den_intra = (scores * D).sum(axis=2)  # (B,t,H)
        # inter contribution (initial state)
        w_init = jnp.exp(a_t - m_new)                   # (B,t,H)
        num_inter = jnp.einsum("bthp,bhpr->bthr", qq * (p_dim**-0.5), Ct)
        num_inter = num_inter * w_init[..., None]
        den_inter = jnp.einsum("bthp,bhp->bth", qq * (p_dim**-0.5), nt) * w_init
        num = num_intra + num_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # chunk-end state update
        F_L = F[:, -1, :]                               # (B,H) total chunk decay
        m_out = jnp.maximum(F_L + mt, (F_L[:, None, :] - F + ii).max(axis=1))
        w_old = jnp.exp(F_L + mt - m_out)               # (B,H)
        w_s = jnp.exp(F_L[:, None, :] - F + ii - m_out[:, None, :])  # (B,s,H)
        C_new = Ct * w_old[..., None, None] + jnp.einsum(
            "bshp,bshr->bhpr", kk * w_s[..., None], vv
        )
        n_new = nt * w_old[..., None] + jnp.einsum("bsh,bshp->bhp", w_s, kk)
        return (C_new, n_new, m_out), h

    C0 = jnp.zeros((B, H, p_dim, p_dim), jnp.float32)
    n0 = jnp.zeros((B, H, p_dim), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(ic, 1, 0), jnp.moveaxis(fc, 1, 0),
    )
    final, hs = jax.lax.scan(body, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, p_dim), final


def mlstm_step(q, k, v, i_g, f_g, state):
    """Single decode step. q,k,v: (B,H,p) f32; i_g,f_g: (B,H) logits."""
    C, n, m = state["C"], state["n"], state["m"]
    p_dim = q.shape[-1]
    lf = jax.nn.log_sigmoid(f_g)
    m_new = jnp.maximum(lf + m, i_g)
    w_old = jnp.exp(lf + m - m_new)
    w_in = jnp.exp(i_g - m_new)
    C_new = C * w_old[..., None, None] + jnp.einsum("bhp,bhr->bhpr", k * w_in[..., None], v)
    n_new = n * w_old[..., None] + k * w_in[..., None]
    num = jnp.einsum("bhp,bhpr->bhr", q * (p_dim**-0.5), C_new)
    den = jnp.einsum("bhp,bhp->bh", q * (p_dim**-0.5), n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_sequential(q, k, v, i_g, f_g):
    """Step-by-step oracle for tests."""
    B, S, H, p_dim = q.shape
    state = {
        "C": jnp.zeros((B, H, p_dim, p_dim), jnp.float32),
        "n": jnp.zeros((B, H, p_dim), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }

    def body(st, xs):
        qq, kk, vv, ii, ff = xs
        h, st = mlstm_step(qq, kk, vv, ii, ff, st)
        return st, h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_g, f_g))
    final, hs = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(hs, 0, 1), final


def mlstm_block(p, x, cfg, chunk: int = 256):
    """xLSTM mLSTM block: up-proj, conv, matrix-memory mixer, gated down-proj.

    Returns (y, final_state) so prefill can seed the decode cache directly.
    """
    B, S, d = x.shape
    di = cfg.d_inner or 2 * d
    H = cfg.n_heads
    pd = di // H
    z = apply_linear(p["w_z"], x)
    u = apply_linear(p["w_up"], x)
    c = jax.nn.silu(_causal_conv(u, p["conv_w"]))
    q = apply_linear(p["wq"], c).reshape(B, S, H, pd).astype(jnp.float32)
    k = apply_linear(p["wk"], c).reshape(B, S, H, pd).astype(jnp.float32)
    v = apply_linear(p["wv"], u).reshape(B, S, H, pd).astype(jnp.float32)
    if_g = apply_linear(p["w_if"], u).astype(jnp.float32)
    i_g, f_g = if_g[..., :H], if_g[..., H:]
    h, (Cf, nf, mf) = _mlstm_chunk(q, k, v, i_g, f_g, chunk)
    h = h.astype(x.dtype)
    W = cfg.conv_width
    conv_tail = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):]
    state = {"C": Cf, "n": nf, "m": mf, "conv": conv_tail}
    h = h.reshape(B, S, di) + u * p["skip"].astype(x.dtype)
    return apply_linear(p["w_down"], h * jax.nn.silu(z)), state


def mlstm_state_init(cfg, batch: int, dtype):
    di = cfg.d_inner or 2 * cfg.d_model
    H = cfg.n_heads
    pd = di // H
    return {
        "C": jnp.zeros((batch, H, pd, pd), jnp.float32),
        "n": jnp.zeros((batch, H, pd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
    }


def mlstm_block_step(p, x, cfg, state):
    """Decode step. x: (B,1,d)."""
    B = x.shape[0]
    di = cfg.d_inner or 2 * cfg.d_model
    H = cfg.n_heads
    pd = di // H
    z = apply_linear(p["w_z"], x)[:, 0]
    u = apply_linear(p["w_up"], x)[:, 0]
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)
    w = p["conv_w"].astype(u.dtype)
    c = jax.nn.silu((hist * w[None]).sum(1))
    q = apply_linear(p["wq"], c).reshape(B, H, pd).astype(jnp.float32)
    k = apply_linear(p["wk"], c).reshape(B, H, pd).astype(jnp.float32)
    v = apply_linear(p["wv"], u).reshape(B, H, pd).astype(jnp.float32)
    if_g = apply_linear(p["w_if"], u).astype(jnp.float32)
    i_g, f_g = if_g[..., :H], if_g[..., H:]
    h, new_inner = mlstm_step(q, k, v, i_g, f_g, state)
    h = h.reshape(B, di).astype(x.dtype) + u * p["skip"].astype(x.dtype)
    y = apply_linear(p["w_down"], (h * jax.nn.silu(z))[:, None])
    return y, {**new_inner, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, hidden-to-hidden recurrence: inherently sequential)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 7)
    p = {
        "w_zifo": linear_init(ks[0], d, 4 * d, dtype=dtype),
        "r_zifo": _init(ks[1], (4, H, hd, hd), scale=1.0 / hd**0.5, dtype=dtype),
        "b_zifo": jnp.zeros((4, d), dtype),
        # post-mixer gated FFN (proj factor 4/3, xLSTM paper)
        "w_up_f": linear_init(ks[2], d, (4 * d) // 3, dtype=dtype),
        "w_gate_f": linear_init(ks[3], d, (4 * d) // 3, dtype=dtype),
        "w_down_f": linear_init(ks[4], (4 * d) // 3, d, dtype=dtype),
    }
    return p


def slstm_state_init(cfg, batch: int, dtype):
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, xt, state, H: int):
    """One sLSTM step. xt: (B, 4d) precomputed input projection (f32)."""
    B = xt.shape[0]
    d = xt.shape[1] // 4
    hd = d // H
    h = state["h"]
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhi,ghij->gbhj", hh, p["r_zifo"].astype(jnp.float32))
    rec = rec.reshape(4, B, d)
    pre = xt.reshape(B, 4, d).transpose(1, 0, 2) + rec + p["b_zifo"].astype(jnp.float32)[:, None]
    z_t = jnp.tanh(pre[0])
    i_log = pre[1]
    f_log = jax.nn.log_sigmoid(pre[2])
    o_t = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(f_log + state["m"], i_log)
    i_p = jnp.exp(i_log - m_new)
    f_p = jnp.exp(f_log + state["m"] - m_new)
    c_new = f_p * state["c"] + i_p * z_t
    n_new = f_p * state["n"] + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_block(p, x, cfg):
    """x: (B,S,d) -> (y, final_state); lax.scan over time (inherent recurrence)."""
    B, S, d = x.shape
    H = cfg.n_heads
    xz = (x @ p["w_zifo"]["w"].astype(x.dtype)).astype(jnp.float32)  # (B,S,4d)
    state = slstm_state_init(cfg, B, x.dtype)

    def body(st, xt):
        st = _slstm_cell(p, xt, st, H)
        return st, st["h"]

    final, hs = jax.lax.scan(body, state, jnp.moveaxis(xz, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    ff = apply_linear(p["w_down_f"], jax.nn.silu(apply_linear(p["w_gate_f"], y)) * apply_linear(p["w_up_f"], y))
    return y + ff, final


def slstm_block_step(p, x, cfg, state):
    """Decode step. x: (B,1,d)."""
    xz = (x[:, 0] @ p["w_zifo"]["w"].astype(x.dtype)).astype(jnp.float32)
    st = _slstm_cell(p, xz, state, cfg.n_heads)
    y = st["h"].astype(x.dtype)[:, None]
    ff = apply_linear(p["w_down_f"], jax.nn.silu(apply_linear(p["w_gate_f"], y)) * apply_linear(p["w_up_f"], y))
    return y + ff, st
