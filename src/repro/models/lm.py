"""Model assembly: layer programs -> init / train loss / prefill / decode.

All layer stacks run as `lax.scan` over stacked params (HLO depth-independent).
Decode threads a cache pytree through the same scans.  Families:

  dense/moe/vlm : decoder-only causal LM (vlm prepends stub patch embeddings)
  encdec        : whisper — bidirectional encoder over stub frame embeddings,
                  causal decoder with per-layer cross attention
  hybrid/ssm    : recurrent mixers (RG-LRU, mLSTM, sLSTM) via recurrent.py
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import batch_axes, constrain, constrain_batch, get_mesh
from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec
from .layers import (
    _init,
    apply_mlp,
    apply_norm,
    cross_entropy_chunked,
    mlp_init,
    norm_init,
    sinusoidal_positions,
    softcap,
)

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    p = {}
    ln = cfg.layer_norm
    if spec.kind in ("attn", "moe"):
        p["ln1"] = norm_init(cfg.d_model, ln, dtype)
        p["attn"] = attn.attention_init(ks[0], cfg, dtype=dtype)
        if cfg.final_softcap is not None:  # gemma2 sandwich norms
            p["ln1_post"] = norm_init(cfg.d_model, ln, dtype)
        if spec.cross_attn:
            p["ln_cross"] = norm_init(cfg.d_model, ln, dtype)
            p["cross"] = attn.attention_init(ks[1], cfg, dtype=dtype)
        if spec.kind == "moe":
            p["ln2"] = norm_init(cfg.d_model, ln, dtype)
            p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        elif spec.has_mlp and cfg.d_ff:
            p["ln2"] = norm_init(cfg.d_model, ln, dtype)
            p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.use_bias,
                                gated=not cfg.layer_norm, dtype=dtype)
            if cfg.final_softcap is not None:
                p["ln2_post"] = norm_init(cfg.d_model, ln, dtype)
    elif spec.kind == "rglru":
        p["ln1"] = norm_init(cfg.d_model, ln, dtype)
        p["mixer"] = rec.rglru_init(ks[0], cfg, dtype)
        if spec.has_mlp and cfg.d_ff:
            p["ln2"] = norm_init(cfg.d_model, ln, dtype)
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.use_bias,
                                gated=True, dtype=dtype)
    elif spec.kind == "mlstm":
        p["ln1"] = norm_init(cfg.d_model, ln, dtype)
        p["mixer"] = rec.mlstm_init(ks[0], cfg, dtype)
    elif spec.kind == "slstm":
        p["ln1"] = norm_init(cfg.d_model, ln, dtype)
        p["mixer"] = rec.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.kind)
    return p


def init_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params = {
        "embed": _init(keys[0], (cfg.vocab_size, cfg.d_model), scale=0.02, dtype=dtype),
        "final_norm": norm_init(cfg.d_model, cfg.layer_norm, dtype),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(keys[1], (cfg.d_model, cfg.vocab_size),
                                  scale=0.02, dtype=dtype)
    for si, seg in enumerate(cfg.segments):
        seg_key = jax.random.fold_in(keys[2], si)

        def unit_init(k):
            return {
                str(j): _layer_init(jax.random.fold_in(k, j), spec, cfg, dtype)
                for j, spec in enumerate(seg.unit)
            }

        stacked = jax.vmap(unit_init)(jax.random.split(seg_key, seg.repeats))
        params["segments"].append({"layers": stacked})
    if cfg.family == "encdec":
        enc_cfg = cfg
        params["enc_segments"] = []
        k = jax.random.fold_in(keys[3], 0)

        def enc_unit_init(kk):
            return {"0": _layer_init(kk, LayerSpec(kind="attn", attn_type="bidir"), enc_cfg, dtype)}

        params["enc_segments"].append(
            {"layers": jax.vmap(enc_unit_init)(jax.random.split(k, cfg.n_enc_layers))}
        )
        params["enc_final_norm"] = norm_init(cfg.d_model, cfg.layer_norm, dtype)
    if cfg.family == "vlm":
        params["vision_adapter"] = _init(keys[4], (cfg.d_model, cfg.d_model),
                                         scale=0.02, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Forward blocks
# ---------------------------------------------------------------------------

def _apply_block(p, x, spec: LayerSpec, cfg: ModelConfig, memory, positions):
    """One layer forward (train/prefill). Returns (x, aux, cache_entries)."""
    aux = jnp.float32(0.0)
    cache = {}

    def gather_seq(h):
        # Megatron-SP: with a seq-sharded residual stream, all-gather S once
        # at each sublayer entry (bf16) — GSPMD then reduce-scatters the
        # sublayer output back to the seq-sharded residual.
        if cfg.seq_shard or cfg.pure_dp:
            return constrain_batch(h, pure_dp=cfg.pure_dp)
        return h

    if spec.kind in ("attn", "moe"):
        h = gather_seq(apply_norm(p["ln1"], x, cfg.norm_eps, cfg.layer_norm))
        y, (k, v) = attn.multihead_attention(p["attn"], h, cfg, spec.attn_type,
                                             positions=positions)
        if "ln1_post" in p:
            y = apply_norm(p["ln1_post"], y, cfg.norm_eps, cfg.layer_norm)
        x = x + y
        cache["k"], cache["v"] = k, v
        if spec.cross_attn and memory is not None:
            h = gather_seq(apply_norm(p["ln_cross"], x, cfg.norm_eps, cfg.layer_norm))
            y, (ck, cv) = attn.multihead_attention(p["cross"], h, cfg, "bidir",
                                                   memory=memory)
            x = x + y
            cache["cross_k"], cache["cross_v"] = ck, cv
        if spec.kind == "moe":
            h = gather_seq(apply_norm(p["ln2"], x, cfg.norm_eps, cfg.layer_norm))
            y, aux = moe_mod.moe_ffn(p["moe"], h, cfg)
            x = x + y
        elif "mlp" in p:
            h = gather_seq(apply_norm(p["ln2"], x, cfg.norm_eps, cfg.layer_norm))
            y = apply_mlp(p["mlp"], h)
            if "ln2_post" in p:
                y = apply_norm(p["ln2_post"], y, cfg.norm_eps, cfg.layer_norm)
            x = x + y
    elif spec.kind == "rglru":
        h = gather_seq(apply_norm(p["ln1"], x, cfg.norm_eps, cfg.layer_norm))
        y, cache = rec.rglru_block(p["mixer"], h, cfg)
        x = x + y
        if "mlp" in p:
            h = gather_seq(apply_norm(p["ln2"], x, cfg.norm_eps, cfg.layer_norm))
            x = x + apply_mlp(p["mlp"], h)
    elif spec.kind == "mlstm":
        h = gather_seq(apply_norm(p["ln1"], x, cfg.norm_eps, cfg.layer_norm))
        y, cache = rec.mlstm_block(p["mixer"], h, cfg)
        x = x + y
    elif spec.kind == "slstm":
        h = gather_seq(apply_norm(p["ln1"], x, cfg.norm_eps, cfg.layer_norm))
        y, cache = rec.slstm_block(p["mixer"], h, cfg)
        x = x + y
    return constrain_batch(x, cfg.seq_shard, cfg.pure_dp), aux, cache


def _run_segments(params_segs, segments, x, cfg, memory, positions, collect_cache=False):
    """Run all segments via lax.scan; optionally collect prefill caches."""
    aux_total = jnp.float32(0.0)
    caches = []
    for seg_params, seg in zip(params_segs, segments):
        def body(carry, layer_p):
            xx, aux = carry
            entries = {}
            for j, spec in enumerate(seg.unit):
                fn = _apply_block
                if cfg.remat:
                    policy = (
                        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                        if cfg.remat_policy == "dots" else None
                    )
                    fn = jax.checkpoint(
                        _apply_block, static_argnums=(2, 3), policy=policy,
                    )
                xx, a, cache = fn(layer_p[str(j)], xx, spec, cfg, memory, positions)
                aux = aux + a
                entries[str(j)] = cache
            return (xx, aux), (entries if collect_cache else 0)

        (x, aux_total), ys = jax.lax.scan(
            body, (x, aux_total), seg_params["layers"],
            unroll=seg.repeats if cfg.unroll_layers else 1,
        )
        caches.append(ys if collect_cache else None)
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def _embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.pos_type == "sinusoidal":
        x = x + sinusoidal_positions(tokens.shape[1], cfg.d_model).astype(x.dtype)[None]
    return x


def _logits_fn(params, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

    def fn(xc):
        logits = xc @ w.astype(xc.dtype)
        return softcap(logits.astype(jnp.float32), cfg.final_softcap)

    return fn


def _assemble_inputs(params, batch, cfg):
    """tokens (+ stub frontend embeddings) -> (x, labels, mask, memory)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    memory = None
    if cfg.family == "encdec":
        enc = batch["enc_embeds"].astype(cdt)
        enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model).astype(cdt)[None]
        memory = enc
    x = _embed_tokens(params, tokens, cfg)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(cdt) @ params["vision_adapter"].astype(cdt)
        x = jnp.concatenate([vis, x], axis=1)
        V = vis.shape[1]
        labels = jnp.concatenate([jnp.zeros((x.shape[0], V), labels.dtype), labels], 1)
        mask = jnp.concatenate([jnp.zeros((x.shape[0], V), jnp.float32), mask], 1)
    return constrain_batch(x, pure_dp=cfg.pure_dp), labels, mask, memory


def _run_encoder(params, memory, cfg):
    if memory is None:
        return None, jnp.float32(0.0)
    enc_segs = [None]
    from repro.configs.base import Segment

    seg = Segment((LayerSpec(kind="attn", attn_type="bidir"),), cfg.n_enc_layers)
    m, aux, _ = _run_segments(params["enc_segments"], [seg], memory, cfg, None, None)
    m = apply_norm(params["enc_final_norm"], m, cfg.norm_eps, cfg.layer_norm)
    return m, aux


# ---------------------------------------------------------------------------
# Train / forward
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg: ModelConfig):
    x, labels, mask, memory = _assemble_inputs(params, batch, cfg)
    memory, enc_aux = _run_encoder(params, memory, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux, _ = _run_segments(params["segments"], cfg.segments, x, cfg, memory, positions)
    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.layer_norm)
    ce = cross_entropy_chunked(_logits_fn(params, cfg), x, labels, mask, cfg.vocab_size)
    return ce + 0.01 * (aux + enc_aux)


def forward_logits(params, batch, cfg: ModelConfig):
    """Full-sequence logits (small models / tests only)."""
    x, _, _, memory = _assemble_inputs(params, batch, cfg)
    memory, _ = _run_encoder(params, memory, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _, _ = _run_segments(params["segments"], cfg.segments, x, cfg, memory, positions)
    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.layer_norm)
    return _logits_fn(params, cfg)(x)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def _empty_layer_cache(spec: LayerSpec, cfg, batch, cache_len, dtype):
    c = {}
    if spec.kind in ("attn", "moe"):
        c = attn.make_cache(cfg, spec.attn_type, batch, cache_len, dtype)
        if spec.cross_attn:
            c["cross_k"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dtype)
            c["cross_v"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dtype)
    elif spec.kind == "rglru":
        c = rec.rglru_state_init(cfg, batch, dtype)
    elif spec.kind == "mlstm":
        c = rec.mlstm_state_init(cfg, batch, dtype)
    elif spec.kind == "slstm":
        c = rec.slstm_state_init(cfg, batch, dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Empty decode cache mirroring the segment structure (stacked on repeats)."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    caches = []
    for seg in cfg.segments:
        def one(_):
            return {
                str(j): _empty_layer_cache(spec, cfg, batch, cache_len, dtype)
                for j, spec in enumerate(seg.unit)
            }

        caches.append(jax.vmap(one)(jnp.arange(seg.repeats)))
    return {"layers": caches, "enc_memory": None, "pos": jnp.int32(0)}


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Run the prompt; returns (cache, last-token logits)."""
    x, _, _, memory = _assemble_inputs(params, batch, cfg)
    memory, _ = _run_encoder(params, memory, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, _, raw_caches = _run_segments(
        params["segments"], cfg.segments, x, cfg, memory, positions, collect_cache=True
    )
    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.layer_norm)
    logits = _logits_fn(params, cfg)(x[:, -1])
    # build decode caches from collected K/V
    dtype = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    cache = init_cache(cfg, B, cache_len, dtype)
    for si, (seg, ys) in enumerate(zip(cfg.segments, raw_caches)):
        for j, spec in enumerate(seg.unit):
            entry = cache["layers"][si][str(j)]
            got = ys[str(j)]  # leaves stacked (repeats, B, S, ...)
            if spec.kind in ("attn", "moe"):
                k, v = got["k"], got["v"]

                def fill(e_k, e_v, e_pos, kk, vv):
                    c = attn.fill_cache({"k": e_k, "v": e_v, "pos": e_pos}, kk, vv, 0)
                    return c["k"], c["v"], c["pos"]

                fk, fv, fp = jax.vmap(fill)(entry["k"], entry["v"], entry["pos"], k, v)
                entry = {**entry, "k": fk, "v": fv, "pos": fp}
                if spec.cross_attn:
                    entry["cross_k"] = got["cross_k"]
                    entry["cross_v"] = got["cross_v"]
            else:  # recurrent: the collected final state IS the decode state
                entry = got
            cache["layers"][si][str(j)] = entry
    cache["enc_memory"] = memory
    cache["pos"] = jnp.int32(S)
    return cache, logits


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens: (B,) int32; pos: scalar. -> (logits (B,V), cache)."""
    B = tokens.shape[0]
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
    if cfg.pos_type == "sinusoidal":
        x = x + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(cdt)[None]
    memory = cache.get("enc_memory")

    new_layers = []
    for si, seg in enumerate(cfg.segments):
        def body(xx, xs):
            layer_p, layer_c = xs
            new_c = {}
            for j, spec in enumerate(seg.unit):
                pj, cj = layer_p[str(j)], layer_c[str(j)]
                if spec.kind in ("attn", "moe"):
                    h = apply_norm(pj["ln1"], xx, cfg.norm_eps, cfg.layer_norm)
                    y, upd = attn.decode_attention(
                        pj["attn"], h, cfg,
                        {"k": cj["k"], "v": cj["v"], "pos": cj["pos"]},
                        pos, spec.attn_type,
                    )
                    if "ln1_post" in pj:
                        y = apply_norm(pj["ln1_post"], y, cfg.norm_eps, cfg.layer_norm)
                    xx = xx + y
                    new_c[str(j)] = {**cj, **upd}
                    if spec.cross_attn:
                        h = apply_norm(pj["ln_cross"], xx, cfg.norm_eps, cfg.layer_norm)
                        y, _ = attn.decode_attention(
                            pj["cross"], h, cfg, None, pos, "bidir",
                            memory_cache={"k": cj["cross_k"], "v": cj["cross_v"]},
                        )
                        xx = xx + y
                    if spec.kind == "moe":
                        h = apply_norm(pj["ln2"], xx, cfg.norm_eps, cfg.layer_norm)
                        y, _ = moe_mod.moe_ffn(pj["moe"], h, cfg)
                        xx = xx + y
                    elif "mlp" in pj:
                        h = apply_norm(pj["ln2"], xx, cfg.norm_eps, cfg.layer_norm)
                        y = apply_mlp(pj["mlp"], h)
                        if "ln2_post" in pj:
                            y = apply_norm(pj["ln2_post"], y, cfg.norm_eps, cfg.layer_norm)
                        xx = xx + y
                elif spec.kind == "rglru":
                    h = apply_norm(pj["ln1"], xx, cfg.norm_eps, cfg.layer_norm)
                    y, st = rec.rglru_step(pj["mixer"], h, cfg, cj)
                    xx = xx + y
                    if "mlp" in pj:
                        h = apply_norm(pj["ln2"], xx, cfg.norm_eps, cfg.layer_norm)
                        xx = xx + apply_mlp(pj["mlp"], h)
                    new_c[str(j)] = st
                elif spec.kind == "mlstm":
                    h = apply_norm(pj["ln1"], xx, cfg.norm_eps, cfg.layer_norm)
                    y, st = rec.mlstm_block_step(pj["mixer"], h, cfg, cj)
                    xx = xx + y
                    new_c[str(j)] = st
                elif spec.kind == "slstm":
                    h = apply_norm(pj["ln1"], xx, cfg.norm_eps, cfg.layer_norm)
                    y, st = rec.slstm_block_step(pj["mixer"], h, cfg, cj)
                    xx = xx + y
                    new_c[str(j)] = st
            return xx, new_c

        x, seg_cache = jax.lax.scan(
            body, x, (params["segments"][si]["layers"], cache["layers"][si]),
            unroll=seg.repeats if cfg.unroll_layers else 1,
        )
        new_layers.append(seg_cache)

    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.layer_norm)
    logits = _logits_fn(params, cfg)(x[:, 0])
    new_cache = {"layers": new_layers, "enc_memory": memory, "pos": pos + 1}
    return logits, new_cache
