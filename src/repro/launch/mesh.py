"""Production mesh construction (a FUNCTION — importing this touches no jax
device state; jax devices are only queried when the function is called)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods in multi-pod mode (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import"
        )
    from repro.distributed.sharding import make_mesh_compat

    return make_mesh_compat(shape, axes, devices=devices[:n])
