"""Step functions (train / prefill / decode) with full sharding annotations.

Used both by the real drivers (train.py, serve.py) and by the dry-run, which
lowers these exact functions against ShapeDtypeStruct inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import batch_axes, get_mesh, param_specs
from repro.models import lm
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, grad_compressor=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.train_loss)(params, batch, cfg)
        if grad_compressor is not None:
            grads = grad_compressor(grads)
        params, opt_state, metrics = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cache["pos"], cfg)

    return decode_step


# ---------------------------------------------------------------------------
# Sharding spec trees
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh=None) -> dict:
    ba = batch_axes(mesh or get_mesh(), cfg.pure_dp)
    return {
        "tokens": P(ba, None),
        "enc_embeds": P(ba, None, None),
        "vision_embeds": P(ba, None, None),
    }


def _decode_batchable(global_batch: int, mesh) -> bool:
    import numpy as np

    ba = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ba])) or 1
    return global_batch % n == 0


def cache_specs(cache_shapes, cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """PartitionSpec tree for the decode cache.

    Sequence axes of KV caches shard on "model" (decode_32k) or
    ("data","model") (long_500k, batch=1) — SP for the cache, flash-decode
    combine inserted by SPMD.  Batch shards on DP axes when divisible.
    """
    mesh = mesh or get_mesh()
    ba = batch_axes(mesh) if _decode_batchable(shape.global_batch, mesh) else ()
    seq_ax = ("data", "model") if shape.global_batch == 1 else "model"

    def leaf(path: str, x):
        nd = len(x.shape)
        if nd == 0:
            return P()
        if path.endswith("pos") and nd <= 2:  # slot position arrays
            return P(*((None,) * (nd - 1) + (seq_ax,)))
        if path.endswith(("/k", "/v", "cross_k", "cross_v")):
            # (repeats, B, S, kv, hd)
            return P(None, ba, seq_ax, None, None)
        if path.endswith("enc_memory") and nd == 3:
            return P(ba, None, None)
        if nd >= 2:  # recurrent states (repeats, B, ...)
            return P(*((None, ba) + (None,) * (nd - 2)))
        return P(*((None,) * nd))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, f"{path}/{i}") for i, v in enumerate(tree))
        if tree is None:
            return None
        return leaf(path, tree)

    return walk(cache_shapes, "")


def sanitize_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop sharded axes that do not divide the dim evenly (argument shardings
    must tile exactly; GSPMD padding only applies to intermediates)."""
    out = []
    for i, el in enumerate(spec):
        if el is None:
            out.append(None)
            continue
        axes = el if isinstance(el, tuple) else (el,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(el if shape[i] % n == 0 else None)
    return P(*out)


def with_shardings(shape_tree, spec_tree, mesh=None):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for AOT lowering)."""
    mesh = mesh or get_mesh()

    def leaf(x, s):
        if x is None:
            return None
        s = sanitize_spec(s, x.shape, mesh)
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s))

    return jax.tree.map(leaf, shape_tree, spec_tree,
                        is_leaf=lambda x: x is None or isinstance(x, jax.ShapeDtypeStruct))


def train_state_structs(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """ShapeDtypeStructs (with shardings) for params, opt state and batch."""
    from repro.launch.inputs import input_specs

    mesh = mesh or get_mesh()
    params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(params_s, cfg.fsdp, mesh, cfg.pure_dp)
    params_sh = with_shardings(params_s, p_specs, mesh)
    opt_s = jax.eval_shape(adamw.init, params_s)
    o_specs = {"m": p_specs, "v": p_specs, "step": P()}
    opt_sh = with_shardings(opt_s, o_specs, mesh)
    raw_batch = input_specs(cfg, shape)
    b_specs = {k: v for k, v in batch_specs(cfg, mesh).items() if k in raw_batch}
    batch_sh = with_shardings(raw_batch, b_specs, mesh)
    return params_sh, opt_sh, batch_sh


def optimized_config(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> ModelConfig:
    """Beyond-paper optimized posture (see EXPERIMENTS.md §Perf):
      * dots-saveable remat (useful-FLOPs ratio 0.69 -> 0.8+)
      * pure DP for small models in train/prefill (TP activation psums
        dominate below ~3B params on a 16-wide model axis)
      * decode: pin attention intermediates to the KV-cache sharding
        (flash-decode; kills the involuntary cache rematerialization)
        + masked cache writes
    """
    import dataclasses

    import numpy as np

    mesh = mesh or get_mesh()
    params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_s))
    kw: dict = {"remat_policy": "dots"}
    # pure DP requires the global batch to occupy every device; prefill_32k
    # (batch 32 < 256 chips) must keep TP or most of the mesh idles — this
    # rule was added after measuring a 4x regression (EXPERIMENTS.md §Perf).
    if (
        shape.mode in ("train", "prefill")
        and n_params < 3e9
        and shape.global_batch % mesh.size == 0
    ):
        kw["pure_dp"] = True
        kw["fsdp"] = True
    if shape.mode == "decode":
        kw["decode_cache_axes"] = (
            ("data", "model") if shape.global_batch == 1 else ("model",)
        )
        kw["cache_update"] = "masked"
    return dataclasses.replace(cfg, **kw)


def serving_config(cfg: ModelConfig, mesh=None) -> ModelConfig:
    """Serving posture: bf16 params; FSDP only when TP-only does not fit HBM.

    Training ZeRO-shards everything; a serving replica keeps weights TP-sharded
    and resident (no per-token all-gather) unless the model exceeds per-chip
    HBM with TP alone (mixtral-8x22b).
    """
    import dataclasses

    import numpy as np

    mesh = mesh or get_mesh()
    params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_s))
    tp = mesh.shape["model"]
    serve_fsdp = cfg.fsdp and (2 * n_params / tp > 8e9)  # bf16, >8GB/chip
    return dataclasses.replace(cfg, param_dtype="bfloat16", fsdp=serve_fsdp)


def decode_state_structs(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """ShapeDtypeStructs for (params, cache, tokens) of a decode cell.

    NOTE: pass a serving_config(cfg) here (bf16 params, serving FSDP rule).
    """
    mesh = mesh or get_mesh()
    params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(params_s, cfg.fsdp, mesh)
    params_sh = with_shardings(params_s, p_specs, mesh)
    B = shape.global_batch
    cache_s = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, B, shape.seq_len)
    )
    c_specs = cache_specs(cache_s, cfg, shape, mesh)
    cache_sh = with_shardings(cache_s, c_specs, mesh)
    ba = batch_axes(mesh) if _decode_batchable(B, mesh) else ()
    tokens_sh = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=NamedSharding(mesh, P(ba)))
    return params_sh, cache_sh, tokens_sh
