"""End-to-end training driver: config -> mesh -> data -> train loop with
LZ4 checkpointing, failure recovery, straggler monitoring, optional gradient
compression.

Examples:
  # ~100M-param qwen3-family model for a few hundred steps on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --scale 100m \
      --steps 200 --batch 8 --seq 256

  # failure-recovery drill (dies at step 7, restarts from the checkpoint):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --scale tiny \
      --steps 20 --simulate-failure 7 --ckpt-every 5
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import Segment, get_config
from repro.data.pipeline import ShardedTokenPipeline
from repro.distributed.fault import RestartPolicy, SimulatedFailure, StepMonitor
from repro.distributed.sharding import param_shardings, single_device_mesh, use_mesh
from repro.launch import steps as steps_mod
from repro.launch.inputs import make_batch
from repro.models import lm
from repro.optim import adamw
from repro.optim.grad_compress import ef_init, quantize_with_error_feedback


def scale_config(cfg, scale: str):
    """Shrink an arch config to a CPU-trainable size, keeping its family."""
    if scale == "full":
        return cfg
    if scale == "tiny":
        return cfg.reduced()
    if scale == "100m":
        segs = tuple(
            dataclasses.replace(s, repeats=max(1, min(s.repeats, 8 // len(s.unit))))
            for s in cfg.segments
        )
        return dataclasses.replace(
            cfg,
            d_model=512, n_heads=8,
            n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 8,
            head_dim=64, d_ff=2048 if cfg.d_ff else 0,
            vocab_size=32000, window=min(cfg.window, 512),
            segments=segs,
            n_layers=sum(len(s.unit) * s.repeats for s in segs),
            lru_width=512 if cfg.lru_width else 0,
            d_inner=1024 if cfg.family == "ssm" else 0,
            n_enc_layers=min(cfg.n_enc_layers, 2), enc_seq=64 if cfg.n_enc_layers else 0,
            vision_tokens=16 if cfg.vision_tokens else 0,
            fsdp=False, compute_dtype="float32",
        )
    raise ValueError(scale)


def train(args) -> dict:
    cfg = scale_config(get_config(args.arch), args.scale)
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, total_steps=args.steps,
        schedule="wsd" if args.arch == "minicpm-2b" else "cosine",
        warmup_steps=max(args.steps // 20, 5),
    )
    mesh = single_device_mesh()
    restart = RestartPolicy()
    monitor = StepMonitor()
    os.makedirs(args.ckpt_dir, exist_ok=True)
    # --shard-compress N: checkpoint leaves compress through the sharded
    # fabric (host-partition path here — block bytes are identical to a
    # mesh run, see distributed/fabric.py — so single-process drills
    # exercise the same container the fleet writes).  getattr: callers that
    # build their own args namespace predate the flag.
    ckpt_engine = None
    if getattr(args, "shard_compress", None):
        from repro.core.engine import LZ4Engine

        ckpt_engine = LZ4Engine(shards=args.shard_compress)
    pipe = ShardedTokenPipeline(
        os.path.join(args.ckpt_dir, "data"), cfg.vocab_size, seed=args.seed
    )
    losses = []

    with use_mesh(mesh):
        params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
        opt_state = adamw.init(params)
        ef = ef_init(params) if args.grad_compress else None
        start_step = 0

        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None and args.resume:
            state_like = {"params": params, "opt": opt_state}
            restored, _ = ckpt.restore(args.ckpt_dir, latest, state_like)
            params, opt_state = restored["params"], restored["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

        def train_step(params, opt_state, ef, batch):
            def loss_fn(p):
                return lm.train_loss(p, batch, cfg)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            if ef is not None:
                grads, ef = quantize_with_error_feedback(grads, ef)
            params, opt_state, metrics = adamw.update(grads, opt_state, params, opt_cfg)
            return params, opt_state, ef, {"loss": loss, **metrics}

        step_fn = jax.jit(train_step)

        step = start_step
        while step < args.steps:
            try:
                monitor.start()
                tokens = pipe.batch(step, args.batch, args.seq)
                batch = {"tokens": jnp.asarray(tokens)}
                extra = make_batch(step, cfg, args.batch, args.seq)
                for k in ("enc_embeds", "vision_embeds"):
                    if k in extra:
                        batch[k] = extra[k]
                        batch["tokens"] = extra["tokens"]
                if args.simulate_failure is not None and step == args.simulate_failure:
                    args.simulate_failure = None  # fail exactly once
                    raise SimulatedFailure(f"injected failure at step {step}")
                params, opt_state, ef, metrics = step_fn(params, opt_state, ef, batch)
                m = monitor.stop()
                loss = float(metrics["loss"])
                losses.append(loss)
                step += 1
                if step % args.log_every == 0 or step == args.steps:
                    print(
                        f"[train] step {step} loss {loss:.4f} "
                        f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                        f"dt {m['step_time']:.2f}s", flush=True,
                    )
                if step % args.ckpt_every == 0 or step == args.steps:
                    ckpt.save(
                        args.ckpt_dir, step, {"params": params, "opt": opt_state},
                        async_write=args.async_ckpt, engine=ckpt_engine,
                    )
            except SimulatedFailure as e:
                wait = restart.record_failure()
                print(f"[train] FAILURE: {e}; restarting in {wait:.1f}s", flush=True)
                time.sleep(min(wait, 0.1))
                latest = ckpt.latest_step(args.ckpt_dir)
                if latest is None:
                    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
                    opt_state = adamw.init(params)
                    step = 0
                else:
                    restored, _ = ckpt.restore(
                        args.ckpt_dir, latest, {"params": params, "opt": opt_state}
                    )
                    params, opt_state = restored["params"], restored["opt"]
                    step = latest
                    print(f"[train] recovered at step {step}", flush=True)
        if monitor.should_remesh():
            print("[train] persistent stragglers detected -> re-mesh requested", flush=True)
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "straggler_events": monitor.straggler_events}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--shard-compress", type=int, default=None, metavar="N",
                    help="compress checkpoints through the sharded fabric "
                         "with N shards (host-partition path)")
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train(args)
    print(f"[train] done; final loss {out['final_loss']:.4f}", flush=True)
    return out


if __name__ == "__main__":
    main()
