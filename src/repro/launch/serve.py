"""Serving driver: batched requests against a (reduced) model on CPU.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.distributed.sharding import single_device_mesh, use_mesh
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(0)
    with use_mesh(single_device_mesh()):
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(cfg, params, max_batch=args.max_batch)
        for uid in range(args.requests):
            plen = int(rng.integers(4, 24))
            engine.add_request(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, plen).tolist(),
                max_new_tokens=args.max_new_tokens,
            ))
        t0 = time.perf_counter()
        done = engine.run()
        dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")
    return done


if __name__ == "__main__":
    main()
