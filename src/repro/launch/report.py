"""Regenerate the EXPERIMENTS.md data tables from experiment JSONs.

  python -m repro.launch.report [--section dryrun|roofline|bench]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import LONG_CONTEXT_ARCHS, all_arch_names

EXP = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments")


def dryrun_table() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(EXP, "dryrun", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        coll = r.get("collectives", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.1f} | "
            f"{r.get('param_bytes', 0)/1e9:.2f} | "
            f"{r.get('memory', {}).get('temp_size_in_bytes', 0)/1e9:.2f} | "
            f"{sum(v['count'] for v in coll.values())} | "
            f"{sum(v['bytes'] for v in coll.values())/1e9:.2f} |"
        )
    head = (
        "| arch | shape | mesh | compile (s) | params (GB, global) | "
        "XLA temp/dev (GB) | #coll ops | coll bytes/dev (GB, sans-scan) |\n"
        "|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def skipped_cells() -> str:
    out = []
    for arch in all_arch_names():
        if arch not in LONG_CONTEXT_ARCHS:
            out.append(f"  * {arch} × long_500k — pure full-attention arch (see DESIGN.md)")
    return "\n".join(out)


def roofline_table() -> str:
    from repro.launch.roofline import load_all, markdown_table

    return markdown_table(load_all())


def bench_summary() -> str:
    out = []
    for name in ("table1", "table2", "table3", "table4", "jax_throughput"):
        p = os.path.join(EXP, "benchmarks", f"{name}.json")
        if os.path.exists(p):
            with open(p) as f:
                out.append(f"### {name}\n```json\n{json.dumps(json.load(f), indent=1)}\n```")
    return "\n\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args(argv)
    if args.section in ("dryrun", "all"):
        print("## Dry-run cells\n")
        print(dryrun_table())
        print("\nSkipped (documented):\n" + skipped_cells())
    if args.section in ("roofline", "all"):
        print("\n## Roofline\n")
        print(roofline_table())
    if args.section in ("bench", "all"):
        print("\n## Benchmarks\n")
        print(bench_summary())


if __name__ == "__main__":
    main()
