"""Batch construction: ShapeDtypeStruct stand-ins (dry-run) and real arrays (tests).

Modality frontends are STUBS per the assignment: `input_specs` provides
precomputed frame embeddings (whisper) / patch embeddings (internvl2) next to
the token stream.  For the VLM the vision tokens occupy the first
`vision_tokens` positions of the sequence, so tokens shrink accordingly and
the total backbone length equals the assigned seq_len.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def batch_dims(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    text_len = shape.seq_len - (cfg.vision_tokens or 0)
    return {"batch": shape.global_batch, "seq": text_len}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B = shape.global_batch
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
    d = batch_dims(cfg, shape)
    specs = {"tokens": jax.ShapeDtypeStruct((B, d["seq"]), jnp.int32)}
    if cfg.family == "encdec":
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), cdt)
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.d_model), cdt)
    return specs


def make_batch(seed: int, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Real (small) batch for tests/examples."""
    rng = np.random.default_rng(seed)
    cdt = jnp.dtype(cfg.compute_dtype)
    text_len = seq - (cfg.vision_tokens or 0)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, text_len)), jnp.int32
        )
    }
    if cfg.family == "encdec":
        out["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.enc_seq, cfg.d_model)), cdt
        )
    if cfg.family == "vlm":
        out["vision_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.vision_tokens, cfg.d_model)), cdt
        )
    return out
