import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the REAL step function (train_step for train
shapes, prefill/decode for serving shapes) against ShapeDtypeStruct inputs
carrying full NamedShardings — no array is ever allocated — then compiles and
records:

  * memory_analysis()  (per-device bytes; analytic fallback on CPU backends)
  * cost_analysis()    (per-device FLOPs / bytes accessed)
  * the collective schedule parsed from the post-SPMD HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)

Results are one JSON per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --skip-existing
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, ModelConfig, cells, get_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod
from repro.optim import adamw

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

_COLL_RE = re.compile(
    r"=\s*([a-z0-9\[\],{}() ]*?)\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE,
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the HLO."""
    stats: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(2).lower()
        # result type(s): everything on the line up to the opcode
        head = line.split("=", 1)
        res_bytes = _shape_bytes(head[1].split(m.group(2))[0]) if len(head) > 1 else 0
        s = stats.setdefault(op, {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += res_bytes
    return stats


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Cost probes: XLA cost_analysis counts while-loop (scan) bodies ONCE, so the
# full-config numbers undercount per-layer work by the trip count.  We lower
# the same cell with segment repeats (1, then 1+e_s) and UNROLLED layer scans,
# giving the exact fixed cost + per-layer deltas; the roofline totals are
#   total = cost(repeats=1) + sum_s (repeats_s - 1) * delta_s.
# ---------------------------------------------------------------------------

def _with_repeats(cfg: ModelConfig, reps: list[int]) -> ModelConfig:
    import dataclasses

    segs = tuple(
        dataclasses.replace(s, repeats=r) for s, r in zip(cfg.segments, reps)
    )
    return dataclasses.replace(
        cfg, segments=segs, unroll_layers=True,
        n_layers=sum(len(s.unit) * s.repeats for s in segs),
    )


def _lower_cell(cfg: ModelConfig, shape, mesh, optimized: bool = False):
    if optimized:
        cfg = steps_mod.optimized_config(cfg, shape, mesh)
    if shape.mode == "train":
        params_sh, opt_sh, batch_sh = steps_mod.train_state_structs(cfg, shape, mesh)
        fn = steps_mod.make_train_step(cfg, adamw.AdamWConfig())
        return jax.jit(fn, donate_argnums=(0, 1)).lower(params_sh, opt_sh, batch_sh)
    cfg = steps_mod.serving_config(cfg, mesh)
    if shape.mode == "prefill":
        params_sh, _, batch_sh = steps_mod.train_state_structs(cfg, shape, mesh)
        fn = steps_mod.make_prefill_step(cfg, cache_len=shape.seq_len + 128)
        return jax.jit(fn).lower(params_sh, batch_sh)
    params_sh, cache_sh, tokens_sh = steps_mod.decode_state_structs(cfg, shape, mesh)
    fn = steps_mod.make_decode_step(cfg)
    return jax.jit(fn, donate_argnums=(1,)).lower(params_sh, cache_sh, tokens_sh)


_DOT_RE = re.compile(r"=\s*[a-z0-9\[\],{} ]+?\s(dot|convolution)\(")


def parse_dot_bytes(hlo_text: str) -> int:
    """Operand+result bytes of every dot — the fused-TPU memory-term floor.

    XLA:CPU barely fuses elementwise chains, so raw `bytes accessed` reflects
    CPU lowering, not TPU HBM traffic; on TPU everything except matmul
    streams, collectives and layer-boundary tensors lives in fused kernels.
    """
    total = 0
    for line in hlo_text.splitlines():
        if not _DOT_RE.search(line):
            continue
        for m in _SHAPE_RE.finditer(line):
            n = 1
            if m.group(2):
                for d in m.group(2).split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    return {
        "flops": float((cost or {}).get("flops", 0.0)),
        "bytes": float((cost or {}).get("bytes accessed", 0.0)),
        "dot_bytes": float(parse_dot_bytes(hlo)),
        "coll_bytes": float(sum(v["bytes"] for v in coll.values())),
        "coll": coll,
    }


def _coll_totals(coll: dict) -> dict:
    return {op: float(v["bytes"]) for op, v in coll.items()}


def probe_costs(cfg: ModelConfig, shape, mesh) -> dict:
    """Extrapolated per-device cost totals for the full depth.

    Baseline at repeats=2 (XLA's SPMD strategy is stable for >=2 unrolled
    layers; repeats=1 triggers different global decisions), increment one
    segment to 3: total = cost(2) + (R_s - 2) * delta_s, verified linear in
    tests/test_dryrun.py.
    """
    nseg = len(cfg.segments)
    base_reps = [2] * nseg
    base = _cost_of(_lower_cell(_with_repeats(cfg, base_reps), shape, mesh).compile())
    keys = ("flops", "bytes", "dot_bytes", "coll_bytes")
    total = {k: base[k] for k in keys}
    coll_total = _coll_totals(base["coll"])
    deltas = []
    for s in range(nseg):
        reps = list(base_reps)
        reps[s] += 1
        probe = _cost_of(_lower_cell(_with_repeats(cfg, reps), shape, mesh).compile())
        delta = {k: probe[k] - base[k] for k in keys}
        delta_coll = {
            op: probe["coll"].get(op, {"bytes": 0})["bytes"] - coll_total.get(op, 0.0)
            for op in set(coll_total) | set(probe["coll"])
        }
        deltas.append({**delta, "coll": delta_coll})
        extra = cfg.segments[s].repeats - 2
        for k in keys:
            total[k] = max(0.0, total[k] + extra * delta[k])
        for op, b in delta_coll.items():
            coll_total[op] = max(0.0, coll_total.get(op, 0.0) + extra * b)
    total["coll_bytes"] = sum(coll_total.values())
    return {"base": {k: base[k] for k in keys}, "deltas": deltas,
            "total": total, "coll_by_op": coll_total}


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if optimized:
        with use_mesh(mesh):
            cfg = steps_mod.optimized_config(cfg, shape, mesh)
    chips = mesh.size
    rec = {
        "arch": arch, "shape": shape_name, "optimized": optimized,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "mode": shape.mode, "time": time.time(),
    }
    t0 = time.time()
    with use_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh)
        scfg = cfg if shape.mode == "train" else steps_mod.serving_config(cfg, mesh)
        params_s = jax.eval_shape(
            lambda: __import__("repro.models.lm", fromlist=["lm"]).init_params(
                jax.random.PRNGKey(0), scfg
            )
        )
        rec["param_bytes"] = _tree_bytes(params_s)
        if shape.mode == "train":
            rec["opt_bytes"] = 2 * rec["param_bytes"]
        if shape.mode == "decode":
            import functools as _ft

            from repro.models import lm as _lm

            cache_s = jax.eval_shape(
                _ft.partial(_lm.init_cache, scfg, shape.global_batch, shape.seq_len)
            )
            rec["cache_bytes"] = _tree_bytes(cache_s)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        rec["cost"] = {
            k: float(v)
            for k, v in (cost or {}).items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")
            )
        }
        try:
            mem = compiled.memory_analysis()
            if mem is not None:
                rec["memory"] = {
                    a: int(getattr(mem, a))
                    for a in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                        "alias_size_in_bytes",
                    )
                    if hasattr(mem, a)
                }
        except Exception as e:  # pragma: no cover - backend dependent
            rec["memory_error"] = str(e)
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_bytes"] = len(hlo)
        if not multi_pod:  # roofline table is single-pod only (see spec)
            t2 = time.time()
            rec["probe"] = probe_costs(cfg, shape, mesh)
            rec["probe_s"] = time.time() - t2
    if verbose:
        coll = sum(v["bytes"] for v in rec["collectives"].values())
        print(
            f"[dryrun] {arch} {shape_name} {rec['mesh']}: "
            f"lower {rec['lower_s']:.1f}s compile {rec['compile_s']:.1f}s "
            f"flops/dev {rec['cost'].get('flops', 0):.3e} "
            f"coll/dev {coll/1e6:.1f}MB",
            flush=True,
        )
    return rec


def run_lz4_cell(multi_pod: bool, scan_impl: str = "associative",
                 use_pallas: bool = False, blocks: int = 8192,
                 hash_bits: int = 8, candidate_impl: str = "sort",
                 verbose: bool = True) -> dict:
    """Dry-run the paper's own workload: the LZ4 engine over a sharded batch
    of 64 KB blocks (embarrassingly parallel over all mesh axes).

    The associative-scan selection keeps the whole program while-loop-free,
    so cost_analysis is exact (no probe extrapolation needed).
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.sharding import NamedSharding

    from repro.core.jax_compressor import _PAD, compress_blocks_records
    from repro.core.lz4_types import MAX_BLOCK

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    rec = {
        "arch": "lz4-engine", "shape": f"blocks{blocks}_{scan_impl}"
        + ("_pallas" if use_pallas else "")
        + ("_scatter" if candidate_impl == "scatter" else ""),
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": mesh.size,
        "mode": "compress", "time": time.time(),
        "bytes_per_step": blocks * MAX_BLOCK,
    }
    with use_mesh(mesh):
        sh = NamedSharding(mesh, P(axes))
        blocks_sh = jax.ShapeDtypeStruct((blocks, MAX_BLOCK + _PAD), jnp.uint8, sharding=sh)
        ns_sh = jax.ShapeDtypeStruct((blocks,), jnp.int32, sharding=sh)

        def step(bufs, ns):
            out = compress_blocks_records(
                bufs, ns, hash_bits=hash_bits, scan_impl=scan_impl,
                use_pallas=use_pallas, candidate_impl=candidate_impl,
            )
            return out.size.astype(jnp.int64).sum(), out.emit.sum()

        t0 = time.time()
        lowered = jax.jit(step).lower(blocks_sh, ns_sh)
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t0
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        rec["cost"] = {
            "flops": float((cost or {}).get("flops", 0.0)),
            "bytes": float((cost or {}).get("bytes accessed", 0.0)),
        }
        rec["collectives"] = parse_collectives(compiled.as_text())
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {"temp_size_in_bytes": int(mem.temp_size_in_bytes)}
        except Exception:
            pass
        rec["probe"] = {  # same schema as LM cells for the roofline reader
            "total": {
                "flops": rec["cost"]["flops"],
                "bytes": rec["cost"]["bytes"],
                "coll_bytes": float(
                    sum(v["bytes"] for v in rec["collectives"].values())
                ),
            },
            "coll_by_op": {k: float(v["bytes"]) for k, v in rec["collectives"].items()},
        }
    if verbose:
        print(
            f"[dryrun] lz4-engine {rec['shape']} {rec['mesh']}: "
            f"compile {rec['compile_s']:.1f}s flops/dev {rec['cost']['flops']:.3e} "
            f"bytes/dev {rec['cost']['bytes']:.3e} "
            f"coll/dev {rec['probe']['total']['coll_bytes']/1e6:.1f}MB",
            flush=True,
        )
    return rec


def cell_path(arch: str, shape_name: str, multi_pod: bool, optimized: bool = False) -> str:
    mesh = ("multi" if multi_pod else "single") + ("_opt" if optimized else "")
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--lz4", action="store_true",
                    help="run the lz4-engine cells (paper's own workload)")
    ap.add_argument("--reprobe", action="store_true",
                    help="refresh only the probe costs of existing cell JSONs")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the beyond-paper optimized posture (see steps.optimized_config)")
    args = ap.parse_args(argv)

    if args.reprobe:
        mesh = make_production_mesh()
        for arch, shape_name in cells():
            path = cell_path(arch, shape_name, False)
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            with use_mesh(mesh):
                t0 = time.time()
                rec["probe"] = probe_costs(get_config(arch), SHAPES[shape_name], mesh)
                rec["probe_s"] = time.time() - t0
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[reprobe] {arch} {shape_name} {rec['probe_s']:.0f}s", flush=True)
        return

    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]
    if args.lz4:
        # associative selection only: it is while-loop-free, so cost_analysis
        # is exact (the sequential variant hides 8192 scan steps from XLA's
        # counter; its wall-clock comparison lives in benchmarks/jax_throughput)
        for multi in meshes:
            rec = run_lz4_cell(multi, scan_impl="associative")
            path = cell_path("lz4-engine", rec["shape"], multi)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        return
    todo = cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape_name in todo:
        for multi in meshes:
            path = cell_path(arch, shape_name, multi, args.optimized)
            if args.skip_existing and os.path.exists(path):
                continue
            try:
                rec = run_cell(arch, shape_name, multi, optimized=args.optimized)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception:
                failures.append((arch, shape_name, multi))
                print(f"[dryrun] FAILED {arch} {shape_name} multi={multi}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}", flush=True)
        sys.exit(1)
    print("[dryrun] all cells OK", flush=True)


if __name__ == "__main__":
    main()
