"""Perf hillclimb driver: lower a cell with config variants and report the
three roofline terms per variant (hypothesis -> change -> measure -> record).

  python -m repro.launch.hillclimb --cell internlm2-train
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time

import jax

from repro.configs.base import SHAPES, get_config
from repro.distributed.sharding import use_mesh
from repro.launch.dryrun import OUT_DIR, _cost_of, _lower_cell, _with_repeats, probe_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops


def measure(cfg, shape, mesh, label: str) -> dict:
    t0 = time.time()
    probe = probe_costs(cfg, shape, mesh)
    tot = probe["total"]
    out = {
        "label": label,
        "compute_s": tot["flops"] / PEAK_FLOPS,
        "memory_s": tot["bytes"] / HBM_BW,
        # fused-TPU memory estimate: dot traffic + collectives (CPU HLO leaves
        # elementwise unfused, inflating raw `bytes accessed`; see DESIGN.md)
        "memory_fused_s": (tot.get("dot_bytes", 0.0) + tot["coll_bytes"]) / HBM_BW,
        "collective_s": tot["coll_bytes"] / LINK_BW,
        "flops": tot["flops"], "bytes": tot["bytes"],
        "dot_bytes": tot.get("dot_bytes", 0.0), "coll_bytes": tot["coll_bytes"],
        "coll_by_op": probe.get("coll_by_op", {}),
        "wall_s": time.time() - t0,
    }
    mf = model_flops(cfg, shape, mesh.size)
    bound = max(out["compute_s"], out["memory_s"], out["collective_s"])
    bound_fused = max(out["compute_s"], out["memory_fused_s"], out["collective_s"])
    out["useful_ratio"] = mf / max(tot["flops"], 1.0)
    out["roofline_fraction"] = (mf / PEAK_FLOPS) / bound if bound else 0.0
    out["roofline_fraction_fused"] = (mf / PEAK_FLOPS) / bound_fused if bound_fused else 0.0
    print(
        f"[{label:>28}] comp {out['compute_s']*1e3:8.1f}ms  "
        f"mem {out['memory_s']*1e3:8.1f}ms (fused {out['memory_fused_s']*1e3:7.1f})  "
        f"coll {out['collective_s']*1e3:8.1f}ms  "
        f"useful {out['useful_ratio']:.2f}  frac {out['roofline_fraction']:.4f}"
        f" (fused {out['roofline_fraction_fused']:.3f})",
        flush=True,
    )
    return out


def cell_internlm2_train(variants=None):
    cfg0 = get_config("internlm2-1.8b")
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh()
    results = []
    with use_mesh(mesh):
        results.append(measure(cfg0, shape, mesh, "baseline (paper-faithful)"))
        results.append(
            measure(dataclasses.replace(cfg0, seq_shard=True), shape, mesh,
                    "H2: megatron-SP residual")
        )
        results.append(
            measure(dataclasses.replace(cfg0, remat_policy="dots"), shape, mesh,
                    "H3: dots-saveable remat")
        )
        results.append(
            measure(dataclasses.replace(cfg0, seq_shard=True, remat_policy="dots"),
                    shape, mesh, "H2+H3 combined")
        )
        results.append(
            measure(dataclasses.replace(cfg0, pure_dp=True, remat_policy="dots"),
                    shape, mesh, "H4: pure-DP (model axis=DP)")
        )
    return results


def cell_gemma2_long_decode():
    """Most collective-bound cell: 500k-token decode, seq-sharded KV cache."""
    cfg0 = get_config("gemma2-9b")
    shape = SHAPES["long_500k"]
    mesh = make_production_mesh()
    from repro.launch.steps import serving_config

    results = []
    with use_mesh(mesh):
        base = serving_config(cfg0, mesh)
        results.append(measure(base, shape, mesh, "baseline (dus cache write)"))
        results.append(
            measure(dataclasses.replace(base, cache_update="masked"), shape, mesh,
                    "H1: masked cache update")
        )
        pinned = dataclasses.replace(base, decode_cache_axes=("data", "model"))
        results.append(
            measure(pinned, shape, mesh, "H2: pin flash-decode sharding")
        )
        results.append(
            measure(dataclasses.replace(pinned, cache_update="masked"), shape, mesh,
                    "H2+H1 pinned + masked")
        )
    return results


def cell_lz4_engine():
    """The paper's own workload: iterate the engine's roofline."""
    from repro.launch.dryrun import run_lz4_cell

    results = []
    for label, kw in [
        ("baseline associative", dict(scan_impl="associative")),
        ("H1: scatter-max candidates", dict(scan_impl="associative", candidate_impl="scatter")),
        ("H2: key-packed sort", dict(scan_impl="associative", candidate_impl="sortkey")),
        ("hash_bits=12 (4K entries)", dict(scan_impl="associative", hash_bits=12)),
    ]:
        rec = run_lz4_cell(False, verbose=False, **kw)
        tot = rec["probe"]["total"]
        out = {
            "label": label,
            "compute_s": tot["flops"] / PEAK_FLOPS,
            "memory_s": tot["bytes"] / HBM_BW,
            "collective_s": tot["coll_bytes"] / LINK_BW,
            "bytes_per_step": rec["bytes_per_step"],
        }
        bound = max(out["compute_s"], out["memory_s"], out["collective_s"])
        out["gbps_per_chip"] = rec["bytes_per_step"] / rec["chips"] / bound * 8 / 1e9
        print(f"[{label:>28}] comp {out['compute_s']*1e3:8.1f}ms mem {out['memory_s']*1e3:8.1f}ms "
              f"coll {out['collective_s']*1e3:8.1f}ms -> {out['gbps_per_chip']:.1f} Gb/s/chip",
              flush=True)
        results.append(out)
    return results


def cell_small_arch_posture():
    """Beyond-paper posture fix for the small archs with padded/replicated
    attention (whisper 12 heads, minicpm 36 heads vs TP=16): pure DP."""
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh()
    results = []
    with use_mesh(mesh):
        for arch in ("whisper-small", "minicpm-2b", "xlstm-125m"):
            cfg0 = get_config(arch)
            results.append(measure(cfg0, shape, mesh, f"{arch} baseline"))
            results.append(
                measure(
                    dataclasses.replace(cfg0, pure_dp=True, remat_policy="dots",
                                        fsdp=True),
                    shape, mesh, f"{arch} pure-DP+dots",
                )
            )
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="internlm2-train")
    args = ap.parse_args(argv)
    fn = {
        "internlm2-train": cell_internlm2_train,
        "gemma2-long-decode": cell_gemma2_long_decode,
        "lz4-engine": cell_lz4_engine,
        "small-arch-posture": cell_small_arch_posture,
    }[args.cell]
    results = fn()
    path = os.path.join(OUT_DIR, "..", f"hillclimb_{args.cell}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
