"""Roofline analysis over the dry-run JSONs (offline post-processing; no jax).

Per (arch x shape) single-pod cell:
    compute   = HLO_FLOPs_per_dev / peak_FLOPs          [s]
    memory    = HLO_bytes_per_dev / HBM_bw              [s]
    collective= collective_bytes_per_dev / link_bw      [s]
(The dry-run HLO is the post-SPMD per-device program, so per-device numbers
divided by per-chip rates equal the global formula totals/(chips x rate).)

MODEL_FLOPS (useful work): 6*N*D for training, 2*N*D for prefill/decode
(forward only), with N = non-embedding params (+ d*V logits matmul) and
N_active for MoE.  The ratio MODEL_FLOPS/HLO_FLOPs exposes remat and padding
waste.

Usage: python -m repro.launch.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPES, LONG_CONTEXT_ARCHS, all_arch_names, get_config

PEAK_FLOPS = 197e12   # TPU v5e bf16 per chip
HBM_BW = 819e9        # B/s per chip
LINK_BW = 50e9        # B/s per ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def layer_param_count(spec, cfg) -> tuple[float, float]:
    """(total, active) params of one layer."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    if spec.kind in ("attn", "moe"):
        attn = d * nq * hd * 2 + d * nkv * hd * 2
        if spec.cross_attn:
            attn *= 2
        if spec.kind == "moe":
            total = attn + d * cfg.n_experts + cfg.n_experts * 3 * d * f
            active = attn + d * cfg.n_experts + cfg.top_k * 3 * d * f
            return total, active
        mlp = (3 if not cfg.layer_norm else 2) * d * f if (spec.has_mlp and f) else 0
        n = attn + mlp
        return n, n
    if spec.kind == "rglru":
        r = cfg.lru_width or d
        n = 2 * d * r + 2 * r * r + r * d + (3 * d * f if f else 0)
        return n, n
    if spec.kind == "mlstm":
        di = cfg.d_inner or 2 * d
        n = 2 * d * di + 3 * di * di + di * 2 * cfg.n_heads + di * d
        return n, n
    if spec.kind == "slstm":
        n = 4 * d * d + 4 * d * (d // cfg.n_heads) + 3 * d * (4 * d // 3)
        return n, n
    raise ValueError(spec.kind)


def model_param_count(cfg) -> tuple[float, float]:
    """(N_total, N_active) excluding the embedding gather, including logits."""
    total = active = cfg.d_model * cfg.vocab_size  # logits matmul
    for seg in cfg.segments:
        for spec in seg.unit:
            t, a = layer_param_count(spec, cfg)
            total += t * seg.repeats
            active += a * seg.repeats
    if cfg.family == "encdec":
        t, _ = layer_param_count(
            type(cfg.segments[0].unit[0])(kind="attn", attn_type="bidir"), cfg
        )
        total += t * cfg.n_enc_layers
        active += t * cfg.n_enc_layers
    return total, active


def model_flops(cfg, shape, chips: int) -> float:
    """Useful FLOPs per step per device."""
    _, n_active = model_param_count(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_active * tokens / chips


def analyze_cell(rec: dict) -> dict | None:
    if "probe" not in rec:
        return None
    if rec["arch"] == "lz4-engine":  # the engine cell reports Gb/s, not 6ND
        tot = rec["probe"]["total"]
        bound = max(tot["flops"] / PEAK_FLOPS, tot["bytes"] / HBM_BW,
                    tot["coll_bytes"] / LINK_BW)
        return {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": tot["flops"] / PEAK_FLOPS,
            "memory_s": tot["bytes"] / HBM_BW,
            "memory_fused_s": tot["bytes"] / HBM_BW,
            "collective_s": tot["coll_bytes"] / LINK_BW,
            "dominant": "memory",
            "model_flops_per_dev": 0.0, "hlo_flops_per_dev": tot["flops"],
            "useful_flops_ratio": 0.0, "roofline_fraction": 0.0,
            "roofline_fraction_fused": 0.0,
            "gbps_per_chip": rec["bytes_per_step"] / rec["chips"] / bound * 8 / 1e9
            if bound else 0.0,
            "coll_by_op": rec["probe"].get("coll_by_op", {}),
        }
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    tot = rec["probe"]["total"]
    compute = tot["flops"] / PEAK_FLOPS
    memory = tot["bytes"] / HBM_BW
    memory_fused = (tot.get("dot_bytes", 0.0) + tot["coll_bytes"]) / HBM_BW
    coll = tot["coll_bytes"] / LINK_BW
    dominant = max(("compute", compute), ("memory", memory), ("collective", coll),
                   key=lambda t: t[1])
    mf = model_flops(cfg, shape, rec["chips"])
    useful = mf / max(tot["flops"], 1.0)
    bound_time = max(compute, memory, coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant[0],
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": tot["flops"],
        "useful_flops_ratio": useful,
        # fraction of roofline-best: time if only the compute term existed on
        # USEFUL flops, over the actual bounding term
        "roofline_fraction": (mf / PEAK_FLOPS) / bound_time if bound_time else 0.0,
        "roofline_fraction_fused": (
            (mf / PEAK_FLOPS) / max(compute, memory_fused, coll)
            if max(compute, memory_fused, coll) else 0.0
        ),
        "memory_fused_s": memory_fused,
        "coll_by_op": rec["probe"].get("coll_by_op", {}),
    }


def suggest(row: dict) -> str:
    if row["dominant"] == "collective":
        return "reshard/overlap: biggest collective is " + (
            max(row["coll_by_op"], key=row["coll_by_op"].get) if row["coll_by_op"] else "?"
        )
    if row["dominant"] == "memory":
        return "cut bytes: remat policy / bf16 master / fused attention"
    if row["useful_flops_ratio"] < 0.5:
        return "compute-bound but wasteful: cut remat/padded-head/masked-attn waste"
    return "compute-bound: near roofline, overlap remaining collectives"


def load_all(optimized: bool = False) -> list[dict]:
    suffix = "*__single_opt.json" if optimized else "*__single.json"
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, suffix))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_cell(rec)
        if row:
            row["optimized"] = optimized
            rows.append(row)
    return rows


def merged_table() -> str:
    base = {(r["arch"], r["shape"]): r for r in load_all(False)}
    opt = {(r["arch"], r["shape"]): r for r in load_all(True)}
    lines = [
        "| arch | shape | dominant | useful/HLO base→opt | roofline frac base→opt | fused frac base→opt |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        b = base[key]
        o = opt.get(key)
        fmt = lambda r, f: f"{r[f]:.3f}" if r else "—"
        lines.append(
            f"| {key[0]} | {key[1]} | {b['dominant']} | "
            f"{b['useful_flops_ratio']:.2f}→{fmt(o,'useful_flops_ratio') if o else '—'} | "
            f"{b['roofline_fraction']:.3f}→{fmt(o,'roofline_fraction') if o else '—'} | "
            f"{b.get('roofline_fraction_fused',0):.3f}→{fmt(o,'roofline_fraction_fused') if o else '—'} |"
        )
    return "\n".join(lines)


def markdown_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | useful/HLO | roofline frac | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {suggest(r)} |"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load_all()
    out = os.path.join(DRYRUN_DIR, "..", "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(f"{r['arch']:>18} {r['shape']:>12} dom={r['dominant']:>10} "
                  f"frac={r['roofline_fraction']:.3f} useful={r['useful_flops_ratio']:.2f}")
    print(f"\n[{len(rows)} cells] -> {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
