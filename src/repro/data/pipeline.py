"""Deterministic, host-sharded token pipeline with LZ4-compressed shards.

The synthetic stream mixes zipf-distributed tokens with repeated n-grams so
the LZ4 stage achieves a real (>1) compression ratio — the shard files on
disk go through the paper's engine and are decompressed on load through the
parallel `LZ4DecodeEngine`.  With ``cache_shards=False`` the pipeline never
materializes a whole shard: each batch row is fetched with
`FrameReader.read_range`, decoding only the 64 KB blocks covering that row's
token slice (the frame block table is the seek index).  The ``decode_engine``
parameter opts the whole pipeline into any executor — pass an
``executor="device"`` engine and every shard decode / row fetch runs its
copy phase inside the jit graph instead of host NumPy.

Restart-friendliness: batches are a pure function of (step, host_id), so a
resumed job consumes exactly the batches it would have seen (exactly-once per
epoch across hosts is asserted in tests).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.decode_engine import FrameReader, default_decode_engine
from repro.core.engine import LZ4Engine
from repro.core.frame import frame_info


def synth_tokens(seed: int, n: int, vocab: int) -> np.ndarray:
    """Zipf tokens with injected n-gram repeats (LZ4-compressible)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, min(vocab, 4096) + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    toks = rng.choice(len(ranks), size=n, p=probs).astype(np.int32)
    # repeat phrases: copy earlier spans forward
    n_rep = n // 64
    for _ in range(n_rep):
        src = rng.integers(0, max(n - 64, 1))
        dst = rng.integers(0, max(n - 32, 1))
        ln = rng.integers(8, 32)
        toks[dst : dst + ln] = toks[src : src + ln]
    return toks % vocab


class ShardedTokenPipeline:
    """Writes LZ4'd token shards at init; serves deterministic (B,S) batches."""

    def __init__(self, data_dir: str, vocab: int, *, n_shards: int = 4,
                 shard_tokens: int = 65536 // 2, host_id: int = 0, n_hosts: int = 1,
                 seed: int = 0, cache_shards: bool = True, decode_engine=None):
        self.vocab = vocab
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.data_dir = data_dir
        self.cache_shards = cache_shards
        self._engine = decode_engine or default_decode_engine()
        os.makedirs(data_dir, exist_ok=True)
        self.shards = []
        for s in range(n_shards):
            path = os.path.join(data_dir, f"shard_{s:04d}.lz4")
            if not os.path.exists(path):
                toks = synth_tokens(seed * 1000 + s, shard_tokens, vocab)
                raw = toks.astype(np.int32).tobytes()
                # Shard files are self-describing frames: no hand-rolled
                # block-count/length prefixes.
                with open(path, "wb") as f:
                    f.write(LZ4Engine().compress(raw))
            self.shards.append(path)
        self._cache: dict[int, np.ndarray] = {}
        self._readers: dict[int, FrameReader] = {}

    def _load_shard(self, s: int) -> np.ndarray:
        if s not in self._cache:
            with open(self.shards[s], "rb") as f:
                raw = self._engine.decode(f.read())
            self._cache[s] = np.frombuffer(raw, np.int32)
        return self._cache[s]

    def _reader(self, s: int) -> FrameReader:
        """Seekable reader over shard s (frame stays compressed in memory)."""
        if s not in self._readers:
            with open(self.shards[s], "rb") as f:
                self._readers[s] = FrameReader(f.read(), engine=self._engine)
        return self._readers[s]

    def _shard_tokens(self, s: int) -> int:
        return self._reader(s).usize // 4 if not self.cache_shards \
            else len(self._load_shard(s))

    def _row(self, s: int, start: int, seq: int) -> np.ndarray:
        if self.cache_shards:
            return self._load_shard(s)[start: start + seq]
        # Random access: decode only the blocks covering this row's slice.
        return np.frombuffer(self._reader(s).read_range(start * 4, seq * 4),
                             np.int32)

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        """Deterministic (batch, seq) int32 tokens for this host at `step`."""
        out = np.empty((batch, seq), np.int32)
        for i in range(batch):
            gidx = (step * batch * self.n_hosts) + self.host_id * batch + i
            s = gidx % len(self.shards)
            n_per = self._shard_tokens(s) - seq
            start = (gidx * 7919) % max(n_per, 1)
            out[i] = self._row(s, start, seq)
        return out

    def compression_ratio(self) -> float:
        raw = comp = 0
        for path in self.shards:
            with open(path, "rb") as f:
                frame = f.read()
            # The block table alone gives the uncompressed size: no decode.
            raw += sum(b["usize"] for b in frame_info(frame)["blocks"])
            comp += len(frame)
        return raw / comp
