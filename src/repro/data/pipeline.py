"""Deterministic, host-sharded token pipeline with LZ4-compressed shards.

The synthetic stream mixes zipf-distributed tokens with repeated n-grams so
the LZ4 stage achieves a real (>1) compression ratio — the shard files on
disk go through the paper's engine and are decompressed on load.

Restart-friendliness: batches are a pure function of (step, host_id), so a
resumed job consumes exactly the batches it would have seen (exactly-once per
epoch across hosts is asserted in tests).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.engine import LZ4Engine
from repro.core.frame import decode_frame


def synth_tokens(seed: int, n: int, vocab: int) -> np.ndarray:
    """Zipf tokens with injected n-gram repeats (LZ4-compressible)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, min(vocab, 4096) + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    toks = rng.choice(len(ranks), size=n, p=probs).astype(np.int32)
    # repeat phrases: copy earlier spans forward
    n_rep = n // 64
    for _ in range(n_rep):
        src = rng.integers(0, max(n - 64, 1))
        dst = rng.integers(0, max(n - 32, 1))
        ln = rng.integers(8, 32)
        toks[dst : dst + ln] = toks[src : src + ln]
    return toks % vocab


class ShardedTokenPipeline:
    """Writes LZ4'd token shards at init; serves deterministic (B,S) batches."""

    def __init__(self, data_dir: str, vocab: int, *, n_shards: int = 4,
                 shard_tokens: int = 65536 // 2, host_id: int = 0, n_hosts: int = 1,
                 seed: int = 0):
        self.vocab = vocab
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.data_dir = data_dir
        os.makedirs(data_dir, exist_ok=True)
        self.shards = []
        for s in range(n_shards):
            path = os.path.join(data_dir, f"shard_{s:04d}.lz4")
            if not os.path.exists(path):
                toks = synth_tokens(seed * 1000 + s, shard_tokens, vocab)
                raw = toks.astype(np.int32).tobytes()
                # Shard files are self-describing frames: no hand-rolled
                # block-count/length prefixes.
                with open(path, "wb") as f:
                    f.write(LZ4Engine().compress(raw))
            self.shards.append(path)
        self._cache: dict[int, np.ndarray] = {}

    def _load_shard(self, s: int) -> np.ndarray:
        if s not in self._cache:
            with open(self.shards[s], "rb") as f:
                raw = decode_frame(f.read())
            self._cache[s] = np.frombuffer(raw, np.int32)
        return self._cache[s]

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        """Deterministic (batch, seq) int32 tokens for this host at `step`."""
        out = np.empty((batch, seq), np.int32)
        for i in range(batch):
            gidx = (step * batch * self.n_hosts) + self.host_id * batch + i
            shard = self._load_shard(gidx % len(self.shards))
            n_per = len(shard) - seq
            start = (gidx * 7919) % max(n_per, 1)
            out[i] = shard[start : start + seq]
        return out

    def compression_ratio(self) -> float:
        raw = comp = 0
        for s, path in enumerate(self.shards):
            arr = self._load_shard(s)
            raw += arr.nbytes
            comp += os.path.getsize(path)
        return raw / comp
