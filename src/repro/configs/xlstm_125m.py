"""Config module for --arch (re-exports from arch_defs; see there)."""
from repro.configs.arch_defs import *  # noqa: F401,F403
