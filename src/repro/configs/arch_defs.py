"""The ten assigned architectures, exactly as specified (one factory each).

Sources are cited in the assignment; layer programs (segments) encode the
per-arch heterogeneity: gemma2 local/global alternation, recurrentgemma
2-recurrent:1-attention, xlstm mLSTM/sLSTM alternation, mixtral SWA+MoE.
Individual modules (`repro.configs.<arch>`) re-export from here so
`--arch <id>` resolves via the registry.
"""
from __future__ import annotations

from .base import LayerSpec, ModelConfig, Segment, register

_ATTN = LayerSpec(kind="attn", attn_type="global")
_SWA = LayerSpec(kind="attn", attn_type="local")
_MOE_SWA = LayerSpec(kind="moe", attn_type="local")
_RGLRU = LayerSpec(kind="rglru")
_MLSTM = LayerSpec(kind="mlstm", has_mlp=False)
_SLSTM = LayerSpec(kind="slstm", has_mlp=False)


@register("whisper-small")
def whisper_small() -> ModelConfig:
    # [arXiv:2212.04356] enc-dec; conv frontend is a stub (input_specs provides
    # precomputed frame embeddings). Sinusoidal positions; LayerNorm + biases.
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        segments=(Segment((LayerSpec(kind="attn", attn_type="global", cross_attn=True),), 12),),
        n_enc_layers=12,
        enc_seq=1500,
        use_bias=True,
        layer_norm=True,
        pos_type="sinusoidal",
        tie_embeddings=True,
        fsdp=False,
    )


@register("internvl2-26b")
def internvl2_26b() -> ModelConfig:
    # [arXiv:2404.16821] InternViT frontend stubbed (256 patch embeddings
    # prepended); backbone is the InternLM2-20B-style decoder.
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        segments=(Segment((_ATTN,), 48),),
        vision_tokens=256,
        rope_theta=1e6,
        fsdp=True,
    )


@register("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    # [arXiv:2401.04088] 8 experts, top-2, sliding-window attention.
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        segments=(Segment((_MOE_SWA,), 32),),
        n_experts=8,
        top_k=2,
        window=4096,
        rope_theta=1e6,
        fsdp=True,
        tie_embeddings=False,
    )


@register("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        segments=(Segment((_MOE_SWA,), 56),),
        n_experts=8,
        top_k=2,
        window=4096,
        rope_theta=1e6,
        fsdp=True,
        tie_embeddings=False,
    )


@register("internlm2-1.8b")
def internlm2_1_8b() -> ModelConfig:
    # [arXiv:2403.17297] llama-like GQA.
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        segments=(Segment((_ATTN,), 24),),
        rope_theta=1e6,
        fsdp=True,
        tie_embeddings=False,
    )


@register("qwen3-1.7b")
def qwen3_1_7b() -> ModelConfig:
    # [hf:Qwen/Qwen3-*] qk_norm, GQA, head_dim 128.
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=6144,
        vocab_size=151936,
        segments=(Segment((_ATTN,), 28),),
        head_dim=128,
        qk_norm=True,
        rope_theta=1e6,
        fsdp=True,
    )


@register("minicpm-2b")
def minicpm_2b() -> ModelConfig:
    # [arXiv:2404.06395] llama-like MHA (kv=36); trained with WSD schedule.
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        segments=(Segment((_ATTN,), 40),),
        fsdp=True,
    )


@register("gemma2-9b")
def gemma2_9b() -> ModelConfig:
    # [arXiv:2408.00118] alternating local(4096)/global, logit softcaps,
    # head_dim 256, sandwich norms.
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=256000,
        segments=(Segment((_SWA, _ATTN), 21),),
        head_dim=256,
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        fsdp=True,
    )


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    # [arXiv:2402.19427] Griffin: (RG-LRU, RG-LRU, local-attn) repeating;
    # 38 layers = 12 full triples + one trailing recurrent pair. MQA (kv=1).
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        segments=(
            Segment((_RGLRU, _RGLRU, _SWA), 12),
            Segment((_RGLRU, _RGLRU), 1),
        ),
        lru_width=4096,
        window=2048,
        fsdp=True,
    )


@register("xlstm-125m")
def xlstm_125m() -> ModelConfig:
    # [arXiv:2405.04517] alternating mLSTM/sLSTM blocks; no separate FFN
    # (d_ff=0): the blocks carry their own up/down projections.
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        segments=(Segment((_MLSTM, _SLSTM), 6),),
        d_inner=1536,
        fsdp=False,
    )
