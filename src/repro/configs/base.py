"""Config system: model configs, layer programs, input shapes, registry.

Every assigned architecture is a `ModelConfig` whose layer stack is a
*program* of segments: `Segment(unit=(LayerSpec,...), repeats=k)`.  Each
segment is executed as one `lax.scan` over stacked parameters, so HLO size is
independent of depth; heterogeneous stacks (gemma2 local/global alternation,
recurrentgemma 2:1 recurrent:attention, xlstm mLSTM/sLSTM) are expressed as
multi-layer units or multiple segments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str            # "attn" | "moe" | "rglru" | "mlstm" | "slstm"
    attn_type: str = "global"   # "global" | "local" (sliding window) | "bidir"
    has_mlp: bool = True        # attach an FFN (dense) after the mixer
    cross_attn: bool = False    # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class Segment:
    unit: tuple[LayerSpec, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: tuple[Segment, ...]
    head_dim: int | None = None
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    use_bias: bool = False         # whisper-style biases + LayerNorm
    layer_norm: bool = False       # LayerNorm instead of RMSNorm
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # hybrid / ssm
    lru_width: int = 0             # rglru recurrence width
    conv_width: int = 4
    d_inner: int = 0               # mlstm inner width (0 -> 2*d_model)
    # enc-dec / vlm frontends (stubs provide precomputed embeddings)
    n_enc_layers: int = 0
    enc_seq: int = 1500            # whisper encoder frames (stub)
    vision_tokens: int = 0         # internvl2 stub patch embeddings
    # positions: "rope" | "sinusoidal" (whisper; param-free)
    pos_type: str = "rope"
    # training
    fsdp: bool = False             # additionally shard big weights on "data"
    remat: bool = True
    # dry-run cost probes: fully unroll layer scans so XLA cost_analysis sees
    # every layer (while-loop bodies are otherwise counted once)
    unroll_layers: bool = False
    # Megatron-style sequence parallelism: shard the residual stream's seq
    # axis on "model" between blocks (norms/elementwise run on S/TP tokens;
    # GSPMD turns TP psums into bf16 all-gather + reduce-scatter pairs)
    seq_shard: bool = False
    # remat policy for the layer scan: "full" (recompute everything) or
    # "dots" (save matmul outputs, recompute elementwise only)
    remat_policy: str = "full"
    # decode KV-cache write: "dus" (dynamic_update_slice; GSPMD gathers a
    # seq-sharded cache at a traced index) or "masked" (iota==pos select;
    # stays local per shard — trades a full-cache HBM write for zero comm)
    cache_update: str = "dus"
    # map the "model" mesh axis to extra data parallelism (no TP): the right
    # posture for small models where TP activation psums dominate
    pure_dp: bool = False
    # mesh axes holding the decode KV-cache sequence dim; pins attention
    # intermediates to the cache layout so GSPMD never rematerializes the
    # cache (flash-decode partial-softmax combine instead)
    decode_cache_axes: tuple | None = None
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale_segments = []
        for seg in self.segments:
            scale_segments.append(Segment(unit=seg.unit, repeats=min(seg.repeats, 1)))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            window=32,
            segments=tuple(scale_segments),
            n_layers=sum(len(s.unit) * min(s.repeats, 1) for s in self.segments),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            lru_width=64 if self.lru_width else 0,
            d_inner=128 if self.family == "ssm" else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.n_enc_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            fsdp=False,
            capacity_factor=8.0,       # no token drops -> decode == train math
            compute_dtype="float32",   # exactness for equivalence tests
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs with sub-quadratic attention (or O(1) state) that run long_500k.
LONG_CONTEXT_ARCHS = {
    "mixtral-8x7b", "mixtral-8x22b", "gemma2-9b", "recurrentgemma-9b", "xlstm-125m",
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the module to trigger registration
        import importlib

        importlib.import_module(
            f"repro.configs.{name.replace('-', '_').replace('.', '_')}"
        )
    return _REGISTRY[name]()


def all_arch_names() -> list[str]:
    return [
        "whisper-small", "internvl2-26b", "mixtral-8x7b", "mixtral-8x22b",
        "internlm2-1.8b", "qwen3-1.7b", "minicpm-2b", "gemma2-9b",
        "recurrentgemma-9b", "xlstm-125m",
    ]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring the long_500k applicability rule."""
    out = []
    for arch in all_arch_names():
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name))
    return out
