"""Pallas TPU kernel: device-side LZ4 byte emission (scatter-emit).

The paper keeps the whole token pipeline on-chip; byte emission was our last
host-side stage (NumPy prefix sums in core/emitter.py).  This kernel closes
the loop: given the per-sequence layout fields (prefix sums computed in XLA,
see kernels/ops.py `emit_bytes`) and the covering-sequence map `seg`, every
output byte is a pure function of its own position — the inverse-scatter
formulation, so the kernel body is elementwise math plus gathers, with no
variable-length writes and no feedback between positions.

Memory layout (mirrors match_extend.py):
  * the input block and the (N_FIELDS, S) per-sequence field table are fully
    VMEM-resident each grid step (256 KB + ~256 KB at defaults — the paper's
    on-chip buffers);
  * `seg` and the output are tiled by TILE positions;
  * the two data-dependent reads — per-sequence fields at `seg[k]` and input
    literals at `anchor + r` — are `jnp.take`, which Mosaic lowers to the
    TPU dynamic-gather unit (v4+); validated with interpret=True here.

The byte math is intentionally duplicated from kernels/ref.py
`emit_bytes_ref` (the jnp oracle): the two paths stay independent and are
asserted bit-identical in tests/test_device_emit.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    F_ANCHOR,
    F_HAS_MATCH,
    F_LIT,
    F_LIT_EXT,
    F_MATCH_EXT,
    F_MLX,
    F_OFF,
    F_START,
    N_FIELDS,
)

TILE = 2048


def _emit_scatter_kernel(total_ref, block_ref, fields_ref, seg_ref, out_ref, *, tile):
    i = pl.program_id(0)
    base = i * tile
    total = total_ref[0]
    blk = block_ref[...]
    f = fields_ref[...]
    seg = seg_ref[...]
    k = base + jax.lax.iota(jnp.int32, tile)

    # Gather the covering sequence's layout fields (dynamic-gather unit).
    st = jnp.take(f[F_START], seg)
    anc = jnp.take(f[F_ANCHOR], seg)
    lit = jnp.take(f[F_LIT], seg)
    le = jnp.take(f[F_LIT_EXT], seg)
    mlx = jnp.take(f[F_MLX], seg)
    me = jnp.take(f[F_MATCH_EXT], seg)
    off = jnp.take(f[F_OFF], seg)
    hm = jnp.take(f[F_HAS_MATCH], seg)

    r = k - st
    token = (jnp.minimum(lit, 15) << 4) | jnp.where(hm > 0, jnp.minimum(mlx, 15), 0)
    lit_ext_byte = jnp.where(r < le, 255, (lit - 15) % 255)
    src = jnp.clip(anc + r - 1 - le, 0, blk.shape[0] - 1)
    lit_byte = jnp.take(blk, src)
    lit_end = 1 + le + lit
    mext_byte = jnp.where(r - (lit_end + 2) < me - 1, 255, (mlx - 15) % 255)
    b = jnp.where(r == 0, token,
        jnp.where(r <= le, lit_ext_byte,
        jnp.where(r <= le + lit, lit_byte,
        jnp.where(r == lit_end, off & 0xFF,
        jnp.where(r == lit_end + 1, (off >> 8) & 0xFF, mext_byte)))))
    out_ref[...] = jnp.where(k < total, b, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def emit_scatter_pallas(block, seg, fields, total, interpret: bool = True):
    """Materialize the compressed block's bytes on device.

    block  : (B,) int32 input byte values (zeroed past the true length)
    seg    : (K,) int32 covering-sequence index per output byte, K % TILE == 0
    fields : (N_FIELDS, S) int32 per-sequence layout rows (ref.F_*)
    total  : (1,) int32 exact compressed size; positions >= total emit 0

    Returns (K,) int32 byte values (cast to uint8 at the ops.py boundary —
    int32 lanes keep the kernel on the VPU's native element type).
    """
    K = seg.shape[0]
    B = block.shape[0]
    S = fields.shape[1]
    assert K % TILE == 0, f"K={K} must be a multiple of {TILE}"
    assert fields.shape[0] == N_FIELDS, fields.shape
    grid = (K // TILE,)
    return pl.pallas_call(
        functools.partial(_emit_scatter_kernel, tile=TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # total: scalar-as-(1,)
            pl.BlockSpec((B,), lambda i: (0,)),            # full block each step
            pl.BlockSpec((N_FIELDS, S), lambda i: (0, 0)),  # full field table
            pl.BlockSpec((TILE,), lambda i: (i,)),         # seg map: tiled
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((K,), jnp.int32),
        interpret=interpret,
    )(total, block, fields, seg)
