"""Pallas TPU kernel: the fused single-pass compression datapath.

This is the whole per-window pipeline of paper Fig. 5 — Word Shift + Hash
Calculation, the Last-Value Table (LVT) candidate lookup, Match Searching,
and the bounded Extended Match (S2) — as ONE kernel over on-chip memory.
Before this kernel the stages ran as separate XLA/Pallas dispatches with
HBM round trips between them, and candidate resolution materialized either
a full 64K-element sort (`candidate_impl="sort"`) or a windows x entries
grid (`"scatter"`); here the LVT is what it is in the hardware: a
2^hash_bits-entry table that LIVES in VMEM and is written/read in window
order.

Dataflow per grid step (one tile of TILE positions):

  1. hash      — the four shifted byte streams are static slices of the
                 VMEM-resident block; word build + Fibonacci hash are pure
                 VPU elementwise ops (fibhash.py's math, inlined).
  2. LVT       — intra-tile: scatter-max positions into a (TILE/pws,
                 2^hash_bits) grid and exclusive-cummax along the window
                 axis (log-depth, the paper's read-before-write port
                 ordering); cross-tile: gather the persistent VMEM table.
                 `cand(p) = max{q : hash(q)=hash(p), win(q) < win(p)}`,
                 exactly `_candidates` — and NO SORT ANYWHERE.
  3. update    — the table absorbs the tile's per-bucket maxima (one
                 vector max), so later tiles see every earlier window's
                 entry: the grid is SEQUENTIAL over tiles, which is the
                 hardware's table write/read ordering made explicit.
  4. match     — rebuild the candidate's word with four gathers (the
                 paper's "data memory" port) and compare; then the bounded
                 `max_match` compare tree from match_extend.py runs on the
                 still-resident block.

The LVT persists across grid steps as a revisited output block (constant
index map — the standard Pallas accumulator pattern, initialized at step
0), so one `pallas_call` covers all 32 tiles of a 64 KB block with zero
intermediate HBM materializations; under vmap each block of a micro-batch
gets its own table.  The data-dependent reads are `jnp.take` (TPU
dynamic-gather unit, v4+); validated with interpret=True on CPU.

The jnp twin is `ref.fused_ref` (whole-block scatter formulation, pinned
bit-identical to the `_candidates` sort oracle at the record level);
tests/test_fused_compress.py asserts kernel == twin elementwise and
kernel == sort oracle end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lz4_types import (
    HASH_PRIME,
    LAST_LITERALS,
    MF_LIMIT,
    MIN_MATCH,
)

TILE = 2048  # positions per grid step (matches fibhash/match_extend tiling)


def _fused_kernel(n_ref, block_ref, cand_ref, len_ref, lvt_ref, *,
                  hash_bits: int, pws: int, max_match: int, tile: int):
    i = pl.program_id(0)
    base = i * tile
    E = 1 << hash_bits
    wins = tile // pws

    # The LVT is a revisited output: every grid step maps to the same
    # (E,) block, so writes from tile i are visible to tile i+1.
    @pl.when(i == 0)
    def _init():
        lvt_ref[...] = jnp.zeros((E,), jnp.int32)

    n = n_ref[0]
    blk = block_ref[...]
    B = blk.shape[0]
    rel = jax.lax.iota(jnp.int32, tile)
    p = base + rel

    # -- 1. word shift + Fibonacci hash (static slices, elementwise) --------
    b0 = jax.lax.dynamic_slice(blk, (base,), (tile,)).astype(jnp.uint32)
    b1 = jax.lax.dynamic_slice(blk, (base + 1,), (tile,)).astype(jnp.uint32)
    b2 = jax.lax.dynamic_slice(blk, (base + 2,), (tile,)).astype(jnp.uint32)
    b3 = jax.lax.dynamic_slice(blk, (base + 3,), (tile,)).astype(jnp.uint32)
    w = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
    h = ((w * jnp.uint32(HASH_PRIME)) >> jnp.uint32(32 - hash_bits)).astype(jnp.int32)

    valid_pos = p <= n - MIN_MATCH

    # -- 2. LVT candidate: intra-tile grid + cross-tile table ---------------
    win = rel // pws
    entry = jnp.where(valid_pos, p + 1, 0)  # 0 = empty bucket
    grid_tab = jnp.zeros((wins, E), jnp.int32).at[win, h].max(entry)
    run_max = jax.lax.associative_scan(jnp.maximum, grid_tab, axis=0)
    excl = jnp.concatenate([jnp.zeros((1, E), jnp.int32), run_max[:-1]], axis=0)
    lvt = lvt_ref[...]
    cand = jnp.maximum(excl[win, h], jnp.take(lvt, h)) - 1
    cand = jnp.where(valid_pos, cand, -1)

    # -- 3. table update: later tiles see this tile's windows ---------------
    lvt_ref[...] = jnp.maximum(lvt, run_max[-1])

    # -- 4. match search (word compare) + bounded extension (S2) ------------
    cc = jnp.clip(cand, 0, B - 1)
    w0 = jnp.take(blk, cc).astype(jnp.uint32)
    w1 = jnp.take(blk, jnp.clip(cc + 1, 0, B - 1)).astype(jnp.uint32)
    w2 = jnp.take(blk, jnp.clip(cc + 2, 0, B - 1)).astype(jnp.uint32)
    w3 = jnp.take(blk, jnp.clip(cc + 3, 0, B - 1)).astype(jnp.uint32)
    wc = w0 | (w1 << 8) | (w2 << 16) | (w3 << 24)
    valid4 = (cand >= 0) & (wc == w) & (p <= n - MF_LIMIT)

    max_extra = jnp.clip(
        n - LAST_LITERALS - (p + MIN_MATCH), 0, max_match - MIN_MATCH
    )
    prefix = jnp.ones((tile,), dtype=jnp.bool_)
    length = jnp.zeros((tile,), dtype=jnp.int32)
    for j in range(max_match - MIN_MATCH):
        cur = jax.lax.dynamic_slice(blk, (base + MIN_MATCH + j,), (tile,))
        cnd = jnp.take(blk, jnp.clip(cc + MIN_MATCH + j, 0, B - 1))
        prefix = prefix & (cur == cnd) & (j < max_extra)
        length = length + prefix.astype(jnp.int32)
    len_ref[...] = jnp.where(valid4, MIN_MATCH + length, 0)
    cand_ref[...] = cand


@functools.partial(
    jax.jit,
    static_argnames=("positions", "hash_bits", "pws", "max_match", "interpret"),
)
def fused_compress_pallas(block, n, positions: int, hash_bits: int = 8,
                          pws: int = 8, max_match: int = 36,
                          interpret: bool | None = None):
    """Candidates + bounded match lengths for every position, one kernel.

    block     : (B,) int32 byte values, zeroed past the true length;
                B >= positions + max_match (the padded compressor block)
    n         : (1,) int32 true block length
    positions : static position count P; P % TILE == 0, TILE % pws == 0
    interpret : None (default) compiles to Mosaic on a TPU backend and
                falls back to the Pallas interpreter everywhere else, so
                `use_pallas=True` actually reaches the hardware kernel on
                TPU while CPU runs stay a correctness check.

    Returns ``(cand, lengths)``: (P,) int32 each — candidate position (-1
    where none/invalid) and full match length (0 where no valid match,
    else in [MIN_MATCH, max_match]), elementwise-equal to `ref.fused_ref`.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    P = positions
    B = block.shape[0]
    E = 1 << hash_bits
    assert P % TILE == 0, f"P={P} must be a multiple of {TILE}"
    assert TILE % pws == 0, f"pws={pws} must divide the tile size {TILE}"
    assert B >= P + max(max_match, MIN_MATCH), \
        "block must be padded past the last position"
    grid = (P // TILE,)
    cand, lengths, _lvt = pl.pallas_call(
        functools.partial(_fused_kernel, hash_bits=hash_bits, pws=pws,
                          max_match=max_match, tile=TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),      # n: scalar-as-(1,)
            pl.BlockSpec((B,), lambda i: (0,)),      # full block each step
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),   # cand: tiled
            pl.BlockSpec((TILE,), lambda i: (i,)),   # lengths: tiled
            pl.BlockSpec((E,), lambda i: (0,)),      # LVT: persistent
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P,), jnp.int32),
            jax.ShapeDtypeStruct((P,), jnp.int32),
            jax.ShapeDtypeStruct((E,), jnp.int32),
        ],
        interpret=interpret,
    )(n, block)
    return cand, lengths
