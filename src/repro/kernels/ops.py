"""jit'd wrappers around the Pallas kernels with pure-jnp fallback dispatch.

`use_pallas` selects the Pallas path (interpret=True on CPU; on a real TPU the
same call sites compile the Mosaic kernels).  The jnp fallback is the oracle
in ref.py — both paths are interchangeable and tested for exact equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lz4_types import MIN_MATCH

from . import ref
from .decode_wave import decode_wave_pallas
from .plan_speculative import plan_spec_pallas
from .emit_scatter import TILE as EMIT_TILE
from .emit_scatter import emit_scatter_pallas
from .fibhash import TILE as HASH_TILE
from .fibhash import fibhash_pallas
from .fused_compress import fused_compress_pallas
from .match_extend import TILE as EXT_TILE
from .match_extend import match_extend_pallas


def _pad_to(x, multiple, value=0):
    P = x.shape[0]
    rem = (-P) % multiple
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("hash_bits", "use_pallas"))
def hash_positions(block_i32, hash_bits: int = 8, use_pallas: bool = False):
    """Word + Fibonacci hash at every position of a (B,) int32 byte block.

    The block must be padded with >= 3 trailing bytes; returns (words, hashes)
    of length B-3 (one per position that has a full 4-byte word).
    """
    B = block_i32.shape[0]
    P = B - 3
    b0 = block_i32[:P]
    b1 = block_i32[1 : P + 1]
    b2 = block_i32[2 : P + 2]
    b3 = block_i32[3 : P + 3]
    if use_pallas:
        b0p, b1p, b2p, b3p = (_pad_to(b, HASH_TILE) for b in (b0, b1, b2, b3))
        w, h = fibhash_pallas(b0p, b1p, b2p, b3p, hash_bits=hash_bits)
        return w[:P], h[:P]
    return ref.fibhash_ref(b0, b1, b2, b3, hash_bits)


@functools.partial(jax.jit, static_argnames=("max_match", "use_pallas"))
def match_lengths(block_i32, cand, valid, n, max_match: int = 36, use_pallas: bool = False):
    """Bounded match length per position (0 where ~valid, else in [4, max_match])."""
    if use_pallas:
        P = cand.shape[0]
        candp = _pad_to(cand, EXT_TILE)
        validp = _pad_to(valid.astype(jnp.bool_), EXT_TILE)
        need = candp.shape[0] + max_match
        blk = block_i32
        if blk.shape[0] < need:
            blk = jnp.concatenate(
                [blk, jnp.zeros((need - blk.shape[0],), blk.dtype)]
            )
        out = match_extend_pallas(
            blk, candp, validp, jnp.asarray([n], jnp.int32), max_match=max_match
        )
        return out[:P]
    return ref.match_extend_ref(block_i32, cand, valid, n, max_match)


@functools.partial(
    jax.jit,
    static_argnames=("positions", "hash_bits", "pws", "max_match", "use_pallas"),
)
def fused_match_candidates(block_i32, n, positions: int, hash_bits: int = 8,
                           pws: int = 8, max_match: int = 36,
                           use_pallas: bool = False):
    """Fused hash -> LVT candidate -> bounded-match datapath (no sort).

    block_i32 : (B,) int32 byte values, zeroed past `n`; B >= positions +
                max_match (the padded compressor block)
    n         : scalar int32 true block length
    positions : static position count P

    Returns ``(cand, lengths)``, both (P,) int32: the LVT candidate per
    position (-1 where none) and the full bounded match length (0 where no
    valid match).  `use_pallas` selects the single-pass VMEM-resident
    kernel (fused_compress.py, grid-sequential LVT) over the whole-block
    jnp twin (ref.fused_ref); both are elementwise-identical.
    """
    if use_pallas:
        return fused_compress_pallas(
            block_i32, jnp.asarray(n, jnp.int32)[None], positions,
            hash_bits=hash_bits, pws=pws, max_match=max_match,
        )
    return ref.fused_ref(block_i32, n, positions, hash_bits, pws, max_match)


def _ext_len(v):
    """Extension byte count for a token-nibble value (literal count or
    match_len - MIN_MATCH): 0 below 15, else 1 + (v - 15) // 255."""
    return jnp.where(v < 15, 0, 1 + (v - 15) // 255)


def _emit_layout(emit, pos, length, offset, n, out_cap: int):
    """Per-sequence output layout + covering-sequence map, all in-graph.

    The XLA half of device-side emission (shared by both `emit_bytes` paths):
    log-depth prefix sums turn the per-window match records into exact byte
    offsets — a cummax recovers each sequence's literal anchor (as in
    `_plan_size`), a cumsum over per-sequence byte sizes places every token —
    then one scatter of sequence ids at those starts plus a cummax over
    output positions yields `seg`, the covering-sequence index of every
    output byte.  The final literals-only sequence is appended as column W.

    Returns (seg (out_cap,) int32, fields (ref.N_FIELDS, W+1) int32,
    total () int32).
    """
    emit = emit.astype(bool)
    pos = pos.astype(jnp.int32)
    length = length.astype(jnp.int32)
    offset = offset.astype(jnp.int32)
    W = emit.shape[0]

    end = jnp.where(emit, pos + length, 0)
    run_end = jax.lax.cummax(end)
    anchor = jnp.concatenate([jnp.zeros((1,), jnp.int32), run_end[:-1]])
    lit = jnp.where(emit, pos - anchor, 0)
    mlx = jnp.where(emit, length - MIN_MATCH, 0)
    lit_ext = jnp.where(emit, _ext_len(lit), 0)
    match_ext = jnp.where(emit, _ext_len(mlx), 0)
    seq_size = jnp.where(emit, 3 + lit_ext + lit + match_ext, 0)
    csum = jnp.cumsum(seq_size)
    starts = csum - seq_size

    final_start = csum[-1]
    final_anchor = run_end[-1]
    final_lit = n - final_anchor
    final_ext = _ext_len(final_lit)
    total = final_start + 1 + final_ext + final_lit

    app = lambda a, v: jnp.concatenate([a.astype(jnp.int32),
                                        jnp.asarray(v, jnp.int32)[None]])
    fields = jnp.stack([
        app(starts, final_start),            # F_START
        app(anchor, final_anchor),           # F_ANCHOR
        app(lit, final_lit),                 # F_LIT
        app(lit_ext, final_ext),             # F_LIT_EXT
        app(mlx, 0),                         # F_MLX
        app(match_ext, 0),                   # F_MATCH_EXT
        app(jnp.where(emit, offset, 0), 0),  # F_OFF
        app(emit.astype(jnp.int32), 0),      # F_HAS_MATCH
    ])

    # seg[k] = index of the sequence covering output byte k: scatter each
    # live sequence's id at its start (non-emitting windows have zero-size
    # sequences — their starts collide with a neighbour's, so they are
    # routed to a dropped out-of-range index), then a cummax forward-fills.
    live = jnp.concatenate([emit, jnp.ones((1,), bool)])
    sidx = jnp.where(live, fields[ref.F_START], out_cap)
    smap = jnp.zeros((out_cap,), jnp.int32).at[sidx].max(
        jnp.arange(W + 1, dtype=jnp.int32) + 1, mode="drop"
    )
    seg = jax.lax.cummax(smap) - 1
    return seg, fields, total.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("out_cap", "use_pallas"))
def emit_bytes(block_i32, emit, pos, length, offset, n, out_cap: int,
               use_pallas: bool = False):
    """Device-side LZ4 byte emission from per-window match records.

    block_i32 : (B,) int32 input byte values, zeroed past `n`
    emit/pos/length/offset : (W,) per-window match records (BlockRecords)
    n         : scalar int32 true block length
    out_cap   : static output buffer size; must exceed the worst-case
                compressed size (literals-only: MAX_BLOCK + 257 + 1)

    Returns ``(out, total)``: a (out_cap,) uint8 buffer whose first `total`
    bytes are the compressed block (bit-identical to
    `repro.core.emitter.emit_block`, the host oracle) and the exact size.
    Layout (prefix sums + seg map) is XLA either way; `use_pallas` selects
    the Pallas byte-materialization kernel over the jnp gather fallback.
    """
    seg, fields, total = _emit_layout(emit, pos, length, offset, n, out_cap)
    if use_pallas:
        segp = _pad_to(seg, EMIT_TILE, value=0)
        out = emit_scatter_pallas(block_i32, segp, fields, total[None])
        return out[:out_cap].astype(jnp.uint8), total
    return ref.emit_bytes_ref(block_i32, seg, fields, total), total


def _span_map(starts, n_valid, out_cap: int):
    """Covering-span index per output position (scatter + cummax fill).

    The decode-side twin of `_emit_layout`'s seg map: scatter each live
    span's slot id at its start (padding slots — index >= `n_valid` — are
    routed to a dropped out-of-range position), then a cummax forward-fills
    so every output byte knows the last span that started at or before it.
    Returns (out_cap,) int32; -1 where no span has started yet.
    """
    S = starts.shape[0]
    slot = jnp.arange(S, dtype=jnp.int32)
    idx = jnp.where(slot < n_valid, starts, out_cap)
    smap = jnp.zeros((out_cap,), jnp.int32).at[idx].max(slot + 1, mode="drop")
    return jax.lax.cummax(smap) - 1


@functools.partial(jax.jit,
                   static_argnames=("out_cap", "rounds", "use_pallas"))
def decode_gather(blk_u8, lit_src, lit_dst, lit_len, match_dst, match_off,
                  n_lit, n_match, out_size, out_cap: int,
                  rounds: int, use_pallas: bool = False):
    """Device-side block decode from a fixed-shape `DevicePlan`.

    The read-path mirror of `emit_bytes`, same split of labour: the span
    layout (scatter + cummax covering maps, gathers of per-span fields) is
    XLA either way; `use_pallas` selects the Pallas pointer-doubling kernel
    over the jnp fallback for the resolve + byte materialization.

    blk_u8    : (B,) uint8 compressed-payload bytes, zeroed past the true
                payload length (B is the static payload cap; uint8 so the
                host->device upload moves payload bytes, not int32 lanes)
    lit_*     : (L,) int32 literal-run arrays (src in block, dst in output,
                length); rows >= `n_lit` are padding
    match_*   : (M,) int32 match arrays (dst in output, back-offset); rows
                >= `n_match` are padding
    out_size  : scalar int32 decoded size (0 for padding rows of a batch)
    out_cap   : static output buffer size (>= any usize, i.e. MAX_BLOCK)
    rounds    : static pointer-doubling depth; `MAX_RESOLVE_ROUNDS` (16)
                covers every valid block, fewer suffice when the plans'
                `n_waves` say so

    Returns (out_cap,) uint8 whose first `out_size` bytes are the decoded
    block — bit-identical to `execute_plan` / `execute_device_plan` (the
    host oracles) and safe under vmap (a stacked micro-batch of plans
    decodes as one dispatch, exactly like the compress side).
    """
    blk_i32 = blk_u8.astype(jnp.int32)
    L = lit_src.shape[0]
    M = match_dst.shape[0]
    k = jnp.arange(out_cap, dtype=jnp.int32)

    li = _span_map(lit_dst, n_lit, out_cap)
    mi = _span_map(match_dst, n_match, out_cap)
    liC = jnp.clip(li, 0, L - 1)
    lit_end = jnp.take(lit_dst, liC) + jnp.take(lit_len, liC)
    is_lit = (li >= 0) & (k < lit_end)
    in_range = k < out_size
    moff = jnp.take(match_off, jnp.clip(mi, 0, M - 1))
    # Literal bytes (and everything past out_size) are fixed points of the
    # source map; match bytes point back by their covering match's offset.
    ptr = jnp.where(is_lit | ~in_range, k, k - moff)
    ptr = jnp.clip(ptr, 0, out_cap - 1)
    lit_blk = jnp.where(is_lit, jnp.take(lit_src, liC) + (k - jnp.take(lit_dst, liC)), 0)

    if use_pallas:
        out = decode_wave_pallas(blk_i32, lit_blk, ptr,
                                 jnp.asarray(out_size, jnp.int32)[None],
                                 rounds=rounds)
        return out.astype(jnp.uint8)
    return ref.decode_gather_ref(blk_i32, lit_blk, ptr, out_size, rounds)


# --- speculative in-graph planning -----------------------------------------
#
# Buffer padding past the block cap: the speculative parser's 0xFF-run table
# is read at index n, so the (B,) buffer must be strictly longer than any
# payload.  128 keeps B lane-aligned for the Pallas path.
SPEC_PAD = 128

# Rows of the (SPEC_STATUS,) int32 status vector returned per block.
SPEC_ERR, SPEC_N_LIT, SPEC_N_MATCH, SPEC_OUT_SIZE, SPEC_OVERFLOW = range(5)
SPEC_STATUS = 5

# Error codes 1..8 are `core.decode_plan._ERR_MESSAGES`; 9 is the serial
# parser's "truncated block: missing token" (no valid final sequence).
SPEC_ERR_MISSING_TOKEN = 9


def _spec_fields(blk_i32, n, use_pallas: bool):
    if use_pallas:
        return plan_spec_pallas(blk_i32, jnp.asarray(n, jnp.int32)[None])
    return ref.plan_fields_ref(blk_i32, n)


@functools.partial(
    jax.jit, static_argnames=("max_lit", "max_match", "out_cap", "use_pallas"))
def plan_speculative(blk_u8, n, max_out, max_lit: int = 8448,
                     max_match: int = 8448, out_cap: int = 65536,
                     use_pallas: bool = False):
    """Parse one block's token stream into `DevicePlan` arrays, in-graph.

    The device-side replacement for `plan_block_fast` + `to_device_plan`:
    the speculative kernel (plan_speculative.py / ref.plan_fields_ref)
    decodes a candidate header at every offset and selects the real chain;
    this XLA half then validates the chain with the host planner's exact
    error codes, lays out output offsets with a cumsum, and compacts the
    headers into fixed-shape plan arrays with one scatter per column.

    blk_u8  : (B,) uint8 payload bytes zeroed past `n`; B > blk_cap
              (pad with `SPEC_PAD`)
    n       : scalar int32 true payload length (<= B - 1)
    max_out : scalar int32 decoded-size limit (usize when known, else
              MAX_BLOCK) — the host planner's `max_out`
    max_lit/max_match/out_cap : static `DevicePlanCaps` shapes

    Returns ``(lit_src, lit_dst, lit_len, match_dst, match_off, match_len,
    status)``: the first six are the zero-padded `DevicePlan` columns,
    bit-identical to ``to_device_plan(plan_block_fast(...))`` for valid
    streams; ``status`` is (SPEC_STATUS,) int32 indexed by ``SPEC_*`` —
    ``status[SPEC_ERR]`` carries the host planner's error code (0 = valid),
    ``status[SPEC_OVERFLOW]`` flags caps overflow (host falls back).  The
    plan columns are garbage whenever err/overflow is set; callers must
    check status first.

    All arithmetic is int32.  That is safe even though the host planner
    sums in int64: per-position fields are < 2^25, and the first invalid
    sequence is validated against prefix sums over *earlier, valid*
    sequences only (each bounded by max_out <= 2^16), so every value that
    can decide accept/reject is exact; wrapped sums can only occur at
    positions after the first error, which never win the argmax below.
    """
    B = blk_u8.shape[0]
    n = jnp.asarray(n, jnp.int32)
    max_out = jnp.asarray(max_out, jnp.int32)
    is_start, lit_start, lit_len, ls_end, off, mlen, flags = _spec_fields(
        blk_u8.astype(jnp.int32), n, use_pallas)
    started = is_start > 0
    trunc_lx = (flags & 1) > 0
    trunc_mx = (flags & 2) > 0
    nonfinal = ls_end != n

    # Output layout: cumsum of per-header contributions (zero off-chain),
    # so prev_total / before_match match the host planner's running total.
    ll = jnp.where(started, lit_len, 0)
    ml = jnp.where(started & nonfinal, mlen, 0)
    cum = jnp.cumsum(ll + ml)
    prev_total = cum - (ll + ml)
    before_match = prev_total + ll
    out_size = cum[-1]

    # Validation, in the host planner's exact priority order: per position
    # the lowest matching code wins, across positions the first bad header.
    err = jnp.zeros((B,), jnp.int32)
    checks = (
        (trunc_lx, 1),                                  # truncated lit len
        (ls_end > n, 2),                                # truncated literals
        (prev_total + lit_len > max_out, 3),            # output exceeds limit
        (nonfinal & (ls_end + 2 > n), 4),               # truncated offset
        (nonfinal & (off == 0), 5),                     # zero offset
        (nonfinal & (off > before_match), 6),           # offset beyond output
        (nonfinal & trunc_mx, 7),                       # truncated match len
        (nonfinal & (before_match + mlen > max_out), 8),  # exceeds limit
    )
    for cond, code in checks:
        err = jnp.where(started & cond & (err == 0), code, err)
    has_err = err > 0
    err_code = jnp.where(jnp.any(has_err), jnp.take(err, jnp.argmax(has_err)),
                         0)
    final_ok = jnp.any(started & (ls_end == n))
    err_code = jnp.where((err_code == 0) & ~final_ok, SPEC_ERR_MISSING_TOKEN,
                         err_code)

    # Compaction: one scatter per DevicePlan column.  Ordinal slots are
    # unique and the scattered values are non-negative for valid streams,
    # so scatter-max over a zero buffer reproduces `to_device_plan`'s
    # zero-padded columns exactly.
    litmask = started & (lit_len > 0)
    lit_ord = jnp.cumsum(litmask.astype(jnp.int32)) - 1
    n_lit = jnp.sum(litmask.astype(jnp.int32))
    lidx = jnp.where(litmask, lit_ord, max_lit)
    zL = jnp.zeros((max_lit,), jnp.int32)
    lit_src_o = zL.at[lidx].max(lit_start, mode="drop")
    lit_dst_o = zL.at[lidx].max(prev_total, mode="drop")
    lit_len_o = zL.at[lidx].max(lit_len, mode="drop")

    matchmask = started & nonfinal
    m_ord = jnp.cumsum(matchmask.astype(jnp.int32)) - 1
    n_match = jnp.sum(matchmask.astype(jnp.int32))
    midx = jnp.where(matchmask, m_ord, max_match)
    zM = jnp.zeros((max_match,), jnp.int32)
    match_dst_o = zM.at[midx].max(before_match, mode="drop")
    match_off_o = zM.at[midx].max(off, mode="drop")
    match_len_o = zM.at[midx].max(mlen, mode="drop")

    overflow = (n_lit > max_lit) | (n_match > max_match) | (out_size > out_cap)
    status = jnp.stack([err_code, n_lit, n_match, out_size,
                        overflow.astype(jnp.int32)])
    return (lit_src_o, lit_dst_o, lit_len_o,
            match_dst_o, match_off_o, match_len_o, status)


@functools.partial(
    jax.jit,
    static_argnames=("out_cap", "max_lit", "max_match", "rounds",
                     "use_pallas", "compute_crc"))
def plan_decode(blk_u8, n, max_out, out_cap: int, max_lit: int,
                max_match: int, rounds: int, use_pallas: bool = False,
                compute_crc: bool = True):
    """Fused plan + execute (+ CRC) for one block, entirely in-graph.

    Chains `plan_speculative` into `decode_gather` (and `crc32_bytes` when
    `compute_crc`), so a vmapped micro-batch of compressed payloads turns
    into decoded bytes in ONE dispatch with no host parse.  Rows whose
    status carries an error or caps overflow decode to zeros (the caller
    raises or falls back from the status vector); `rounds` should be
    `MAX_RESOLVE_ROUNDS` — with no host plan there is no `n_waves` to
    shrink it adaptively.

    Returns ``(out, status, crc)``: (out_cap,) uint8 decoded bytes,
    the (SPEC_STATUS,) int32 status from `plan_speculative`, and a ()
    uint32 CRC-32 of the decoded payload (0 when `compute_crc` is off).
    """
    (lit_src, lit_dst, lit_len, match_dst, match_off, _match_len,
     status) = plan_speculative(
        blk_u8, n, max_out, max_lit=max_lit, max_match=max_match,
        out_cap=out_cap, use_pallas=use_pallas)
    ok = (status[SPEC_ERR] == 0) & (status[SPEC_OVERFLOW] == 0)
    out_size = jnp.where(ok, status[SPEC_OUT_SIZE], 0)
    out = decode_gather(blk_u8, lit_src, lit_dst, lit_len, match_dst,
                        match_off, status[SPEC_N_LIT], status[SPEC_N_MATCH],
                        out_size, out_cap=out_cap, rounds=rounds,
                        use_pallas=use_pallas)
    crc = crc32_bytes(out, out_size) if compute_crc else jnp.uint32(0)
    return out, status, crc


@functools.lru_cache(maxsize=1)
def _crc_slice8_tables():
    """The 8 x 256 slice-by-8 lookup tables for CRC-32 (IEEE, reflected —
    zlib/binascii-compatible).  Built once on host; embedded in the graph
    as a constant so the checksum runs device-side."""
    import numpy as np

    poly = 0xEDB88320
    t = np.zeros((8, 256), np.uint32)
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ (poly if c & 1 else 0)
        t[0, i] = c
    for k in range(1, 8):
        prev = t[k - 1]
        t[k] = (prev >> 8) ^ t[0, prev & 0xFF]
    return t


@jax.jit
def crc32_bytes(data_u8, n):
    """CRC-32 of ``data_u8[:n]``, entirely in-graph (slice-by-8).

    data_u8 : (K,) uint8 buffer (content past `n` is ignored)
    n       : scalar int32 byte count, 0 <= n <= K

    Returns a () uint32 equal to ``binascii.crc32(bytes(data_u8[:n]))`` —
    the frame's `block_crc`.  Each scan step folds 8 bytes through the
    precomputed tables (the standard slice-by-8 formulation); a masked
    byte-serial variant of the same step handles the ragged tail, so `n`
    stays a traced value and one compiled graph covers every block size.
    Used by the decode engine so `decode_to_device(verify=True)` can check
    integrity WITHOUT fetching the decoded payload to the host.
    """
    K = data_u8.shape[0]
    pad = (-K) % 8
    d = data_u8.astype(jnp.uint32)
    if pad:
        d = jnp.concatenate([d, jnp.zeros((pad,), jnp.uint32)])
    chunks = d.reshape(-1, 8)
    T = jnp.asarray(_crc_slice8_tables())
    n = jnp.asarray(n, jnp.int32)

    def step(crc, xs):
        chunk, s = xs
        base = s * 8
        # Full chunk: fold 4 bytes into the running crc, then one table
        # lookup per byte of the 8-byte slice.
        x = crc ^ (chunk[0] | (chunk[1] << 8) | (chunk[2] << 16)
                   | (chunk[3] << 24))
        full = (T[7, x & 0xFF] ^ T[6, (x >> 8) & 0xFF]
                ^ T[5, (x >> 16) & 0xFF] ^ T[4, (x >> 24) & 0xFF]
                ^ T[3, chunk[4]] ^ T[2, chunk[5]]
                ^ T[1, chunk[6]] ^ T[0, chunk[7]])
        # Ragged tail: the same 8 bytes one at a time, each masked by n.
        c = crc
        for j in range(8):
            upd = T[0, (c ^ chunk[j]) & 0xFF] ^ (c >> 8)
            c = jnp.where(base + j < n, upd, c)
        return jnp.where(base + 8 <= n, full, c), None

    steps = jnp.arange(chunks.shape[0], dtype=jnp.int32)
    crc0 = jnp.uint32(0xFFFFFFFF)
    crc, _ = jax.lax.scan(step, crc0, (chunks, steps))
    return crc ^ jnp.uint32(0xFFFFFFFF)
