"""jit'd wrappers around the Pallas kernels with pure-jnp fallback dispatch.

`use_pallas` selects the Pallas path (interpret=True on CPU; on a real TPU the
same call sites compile the Mosaic kernels).  The jnp fallback is the oracle
in ref.py — both paths are interchangeable and tested for exact equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .fibhash import TILE as HASH_TILE
from .fibhash import fibhash_pallas
from .match_extend import TILE as EXT_TILE
from .match_extend import match_extend_pallas


def _pad_to(x, multiple, value=0):
    P = x.shape[0]
    rem = (-P) % multiple
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("hash_bits", "use_pallas"))
def hash_positions(block_i32, hash_bits: int = 8, use_pallas: bool = False):
    """Word + Fibonacci hash at every position of a (B,) int32 byte block.

    The block must be padded with >= 3 trailing bytes; returns (words, hashes)
    of length B-3 (one per position that has a full 4-byte word).
    """
    B = block_i32.shape[0]
    P = B - 3
    b0 = block_i32[:P]
    b1 = block_i32[1 : P + 1]
    b2 = block_i32[2 : P + 2]
    b3 = block_i32[3 : P + 3]
    if use_pallas:
        b0p, b1p, b2p, b3p = (_pad_to(b, HASH_TILE) for b in (b0, b1, b2, b3))
        w, h = fibhash_pallas(b0p, b1p, b2p, b3p, hash_bits=hash_bits)
        return w[:P], h[:P]
    return ref.fibhash_ref(b0, b1, b2, b3, hash_bits)


@functools.partial(jax.jit, static_argnames=("max_match", "use_pallas"))
def match_lengths(block_i32, cand, valid, n, max_match: int = 36, use_pallas: bool = False):
    """Bounded match length per position (0 where ~valid, else in [4, max_match])."""
    if use_pallas:
        P = cand.shape[0]
        candp = _pad_to(cand, EXT_TILE)
        validp = _pad_to(valid.astype(jnp.bool_), EXT_TILE)
        need = candp.shape[0] + max_match
        blk = block_i32
        if blk.shape[0] < need:
            blk = jnp.concatenate(
                [blk, jnp.zeros((need - blk.shape[0],), blk.dtype)]
            )
        out = match_extend_pallas(
            blk, candp, validp, jnp.asarray([n], jnp.int32), max_match=max_match
        )
        return out[:P]
    return ref.match_extend_ref(block_i32, cand, valid, n, max_match)
