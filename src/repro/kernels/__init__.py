"""Pallas TPU kernels for the paper's compute hot-spots (hash + extended match).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrappers), ref.py (pure-jnp oracles).  Validated with interpret=True
on CPU; the TARGET is TPU v5e (see module docstrings for the Mosaic mapping).
"""
