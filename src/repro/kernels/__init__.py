"""Pallas TPU kernels for the paper's compute hot-spots.

Kernels: fused_compress.py (the single-pass hash -> LVT candidate ->
bounded-match datapath of paper Fig. 5, VMEM-resident table, grid-
sequential window ordering — `candidate_impl="fused"`), fibhash.py (word
build + Fibonacci hash), match_extend.py (bounded S2 match extension) —
the two stages the fused kernel subsumes, kept as the staged path —
emit_scatter.py (device-side byte emission — the write path's last stage,
so compressed bytes never round-trip through host NumPy), decode_wave.py
(device-side plan execution — pointer-doubling source resolve + byte
gather, the read path's mirror of emit_scatter).  ops.py additionally
carries `crc32_bytes`, the in-graph slice-by-8 CRC-32 that keeps verified
device restores free of content fetches.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrappers), ref.py (pure-jnp oracles).  Validated with interpret=True
on CPU; the TARGET is TPU v5e (see module docstrings for the Mosaic mapping).
"""
