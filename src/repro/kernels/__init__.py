"""Pallas TPU kernels for the paper's compute hot-spots.

Kernels: fibhash.py (word build + Fibonacci hash), match_extend.py (bounded
S2 match extension), emit_scatter.py (device-side byte emission — the write
path's last stage, so compressed bytes never round-trip through host NumPy),
decode_wave.py (device-side plan execution — pointer-doubling source resolve
+ byte gather, the read path's mirror of emit_scatter).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrappers), ref.py (pure-jnp oracles).  Validated with interpret=True
on CPU; the TARGET is TPU v5e (see module docstrings for the Mosaic mapping).
"""
