"""Pallas TPU kernel: PWS-way Fibonacci hash (paper Fig. 5 "Hash Calculation").

The FPGA uses 4 DSP48 slices per multiplier; the TPU-native mapping is the
VPU's elementwise int32 multiply over (8,128) vregs — every position's hash is
computed in the same "cycle" (fully data-parallel), which is exactly the
feedforward property the paper engineers for.

Tiling: positions are tiled into VMEM blocks of TILE elements (lane-aligned,
multiple of 1024).  The four shifted byte streams are separate inputs so the
kernel body is pure elementwise ops — no gathers, no cross-lane traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lz4_types import HASH_PRIME

TILE = 2048  # positions per grid step; 8 vregs of int32


def _fibhash_kernel(b0_ref, b1_ref, b2_ref, b3_ref, w_ref, h_ref, *, hash_bits: int):
    w = (
        b0_ref[...].astype(jnp.uint32)
        | (b1_ref[...].astype(jnp.uint32) << 8)
        | (b2_ref[...].astype(jnp.uint32) << 16)
        | (b3_ref[...].astype(jnp.uint32) << 24)
    )
    h = (w * jnp.uint32(HASH_PRIME)) >> jnp.uint32(32 - hash_bits)
    w_ref[...] = w.astype(jnp.int32)
    h_ref[...] = h.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("hash_bits", "interpret"))
def fibhash_pallas(b0, b1, b2, b3, hash_bits: int = 8, interpret: bool = True):
    """(P,) int32 shifted byte streams -> (word_i32, hash_i32), P % TILE == 0."""
    P = b0.shape[0]
    assert P % TILE == 0, f"P={P} must be a multiple of {TILE}"
    grid = (P // TILE,)
    spec = pl.BlockSpec((TILE,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_fibhash_kernel, hash_bits=hash_bits),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((P,), jnp.int32),
            jax.ShapeDtypeStruct((P,), jnp.int32),
        ],
        interpret=interpret,
    )(b0, b1, b2, b3)
