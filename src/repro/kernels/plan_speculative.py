"""Pallas TPU kernel: speculative in-graph LZ4 sequence parsing.

The plan-side twin of decode_wave.py.  The device decode executor used to
parse token streams on host (`plan_block_fast` in core/decode_plan.py) —
the last O(n) host stage in the restore path.  This kernel removes it by
speculating: it decodes a CANDIDATE sequence header at EVERY byte offset
of the compressed block (token nibbles, 0xFF-run literal/match length
extensions, the 16-bit back offset, the next-header position — all pure
functions of the offset once the 0xFF-run table exists), then selects the
single chain actually reachable from offset 0 with log-depth pointer
doubling over the next[] map.  The approach is Sitaridi et al.'s
massively-parallel speculative decompression (PAPERS.md) mapped onto the
covering-sequence machinery this repo already uses for decode.

Two log-depth passes, both VMEM-resident at the 64 KB block scale:

    ffrun[i]  (0xFF-run table)  — suffix-min doubling over "first
              non-0xFF position at or after i", ceil(log2(B)) shifts
    chain     mark = {0}; per round:  mark |= mark scattered through
              jump;  jump = jump[jump]   (reachable set doubles per round)

Headers are at least 3 bytes apart, so a 64 KB block chains < 2^15 deep
and 16 rounds always converge — no data-dependent control flow, no host
fallback for well-formed streams.  The field math reproduces
`plan_block_fast` byte for byte including its clamped reads, so the XLA
validator downstream (`kernels/ops.py` `plan_speculative`) rejects
malformed streams with error codes identical to the host oracle's.

The gathers are `jnp.take` and the chain union is a scatter-max
(`.at[].max`), per the emit_scatter.py precedent; validated with
interpret=True here.  The math is intentionally duplicated from
kernels/ref.py `plan_fields_ref` (the jnp oracle): the two paths stay
independent and are asserted bit-identical in tests/test_plan_speculative.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Doubling depth of the chain-select pass: 2^16 hops covers any sequence
# chain a 64 KB block can contain (headers are >= 3 bytes apart).
CHAIN_ROUNDS = 16


def _plan_spec_kernel(n_ref, blk_ref, start_ref, lit_start_ref, lit_len_ref,
                      ls_end_ref, off_ref, mlen_ref, flags_ref, *,
                      chain_rounds):
    B = blk_ref.shape[0]
    n = n_ref[0]
    blk = blk_ref[...]
    idx = jax.lax.iota(jnp.int32, B)
    inb = idx < n
    nm1 = jnp.maximum(n - 1, 0)

    # 0xFF-run table by suffix-min doubling: m[i] converges to the first
    # non-0xFF position at or after i; the run length is m[i] - i.
    m = jnp.where((blk == 255) & inb, B, idx)
    s = 1
    while s < B:
        m = jnp.minimum(m, jnp.take(m, jnp.minimum(idx + s, B - 1)))
        s <<= 1
    ffrun = m - idx

    # Literal half of the candidate header at every offset.
    lit_nib = blk >> 4
    has_lx = lit_nib == 15
    r1 = jnp.take(ffrun, jnp.minimum(idx + 1, B - 1))
    term1 = idx + 1 + r1
    t1b = jnp.take(blk, jnp.minimum(term1, nm1))
    lit_len = jnp.where(has_lx, r1 * 255 + t1b + 15, lit_nib)
    lit_start = idx + 1 + jnp.where(has_lx, 1 + r1, 0)
    ls_end = lit_start + lit_len

    # Match half: offset bytes at ls_end, extension run after them.
    m_nib = blk & 15
    has_mx = m_nib == 15
    o0 = jnp.minimum(ls_end, nm1)
    off = jnp.take(blk, o0) | (jnp.take(blk, jnp.minimum(o0 + 1, nm1)) << 8)
    r2 = jnp.take(ffrun, jnp.minimum(ls_end + 2, n))
    term2 = ls_end + 2 + r2
    t2b = jnp.take(blk, jnp.minimum(term2, nm1))
    mlen = jnp.where(has_mx, r2 * 255 + t2b + 19, m_nib + 4)
    nxt = ls_end + 2 + jnp.where(has_mx, r2 + 1, 0)

    # Chain select: union the set reachable from offset 0 through its
    # 2^k-hop successors, then square the pointer map.  next[] strictly
    # advances (headers >= 3 bytes), so chains exit via the fixed point n.
    jump = jnp.where(inb, jnp.minimum(nxt, n), idx)
    mark = (idx == 0).astype(jnp.int32)
    for _ in range(chain_rounds):
        mark = mark.at[jump].max(mark, mode="drop")
        jump = jnp.take(jump, jump)

    start_ref[...] = jnp.where(inb, mark, 0)
    lit_start_ref[...] = lit_start
    lit_len_ref[...] = lit_len
    ls_end_ref[...] = ls_end
    off_ref[...] = off
    mlen_ref[...] = mlen
    flags_ref[...] = (has_lx & (term1 >= n)).astype(jnp.int32) | (
        (has_mx & (term2 >= n)).astype(jnp.int32) << 1)


@functools.partial(jax.jit, static_argnames=("chain_rounds", "interpret"))
def plan_spec_pallas(block, n, chain_rounds: int = CHAIN_ROUNDS,
                     interpret: bool = True):
    """Speculatively parse one block's candidate headers on device.

    block        : (B,) int32 compressed-payload byte values, zeroed past
                   n; B must be strictly greater than any n (the run
                   table is read at index n)
    n            : (1,) int32 true payload length
    chain_rounds : static chain-select doubling depth

    Returns seven (B,) int32 arrays (is_start, lit_start, lit_len, ls_end,
    off, mlen, flags) — field semantics documented on kernels/ref.py
    `plan_fields_ref`, validation/compaction in kernels/ops.py
    `plan_speculative`.
    """
    B = block.shape[0]
    return pl.pallas_call(
        functools.partial(_plan_spec_kernel, chain_rounds=chain_rounds),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),   # n: scalar-as-(1,)
            pl.BlockSpec((B,), lambda i: (0,)),   # full compressed block
        ],
        out_specs=[pl.BlockSpec((B,), lambda i: (0,))] * 7,
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.int32)] * 7,
        interpret=interpret,
    )(n, block)
