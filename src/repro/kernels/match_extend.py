"""Pallas TPU kernel: bounded extended-match (paper Section III-B, Fig. 4b).

This is the S2 datapath: because the match length is capped at `max_match`,
the whole extension is a *fixed-depth* compare tree — no feedback loop.  On
the FPGA that means pipeline registers can be inserted freely; on the TPU it
means the loop fully unrolls into `max_match - 4` vectorized compare/accumulate
steps over VMEM-resident data with a static schedule.

Memory layout:
  * The entire 64 KB block lives in VMEM as int32 (256 KB) — the exact
    analogue of the paper's on-chip input buffer ("compatible with the L1
    cache", Section IV-A).  Every grid step sees the whole block (BlockSpec
    maps all tiles to block 0) while candidate indices/outputs are tiled.
  * `block[p + 4 + j]` for a position tile is a *static* slice (p = base +
    iota), emitted with pl.dslice on the scalar base — no gather.
  * `block[cand + 4 + j]` is a genuine data-dependent read: candidates point
    anywhere earlier in the block.  It is expressed as `jnp.take`, which
    Mosaic lowers to the TPU dynamic-gather unit (v4+); in this container it
    is validated with interpret=True.  This read is the paper's "data memory"
    port in Fig. 5 — one read per position per j, exactly PWS x (L_max-4)
    byte-compares per window, same as the hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lz4_types import LAST_LITERALS, MIN_MATCH

TILE = 2048


def _match_extend_kernel(
    n_ref, block_ref, cand_ref, valid_ref, len_ref, *, max_match: int, tile: int
):
    i = pl.program_id(0)
    base = i * tile
    n = n_ref[0]
    blk = block_ref[...]
    B = blk.shape[0]
    cand = cand_ref[...]
    p = base + jax.lax.iota(jnp.int32, tile)
    max_extra = jnp.clip(
        n - LAST_LITERALS - (p + MIN_MATCH), 0, max_match - MIN_MATCH
    )
    prefix = jnp.ones((tile,), dtype=jnp.bool_)
    length = jnp.zeros((tile,), dtype=jnp.int32)
    for j in range(max_match - MIN_MATCH):
        # Static-offset slice of the block for the current positions...
        cur = jax.lax.dynamic_slice(blk, (base + MIN_MATCH + j,), (tile,))
        # ...and a dynamic gather for the candidates (TPU dynamic-gather unit).
        cnd = jnp.take(blk, jnp.clip(cand + MIN_MATCH + j, 0, B - 1), axis=0)
        prefix = prefix & (cur == cnd) & (j < max_extra)
        length = length + prefix.astype(jnp.int32)
    len_ref[...] = jnp.where(valid_ref[...], MIN_MATCH + length, 0)


@functools.partial(jax.jit, static_argnames=("max_match", "interpret"))
def match_extend_pallas(block, cand, valid, n, max_match: int = 36, interpret: bool = True):
    """Bounded match lengths for every position.

    block : (B,) int32, B >= P + max_match (padded); the full on-chip buffer
    cand  : (P,) int32 candidate positions, P % TILE == 0
    valid : (P,) bool
    n     : (1,) int32 true length
    """
    P = cand.shape[0]
    B = block.shape[0]
    assert P % TILE == 0, f"P={P} must be a multiple of {TILE}"
    assert B >= P + max_match, "block must be padded past the last position"
    grid = (P // TILE,)
    return pl.pallas_call(
        functools.partial(_match_extend_kernel, max_match=max_match, tile=TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),          # n: scalar-as-(1,)
            pl.BlockSpec((B,), lambda i: (0,)),          # full block each step
            pl.BlockSpec((TILE,), lambda i: (i,)),       # candidates: tiled
            pl.BlockSpec((TILE,), lambda i: (i,)),       # valid: tiled
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.int32),
        interpret=interpret,
    )(n, block, cand, valid)
