"""Pallas TPU kernel: device-side block decode (pointer-doubling resolve).

The read-path twin of emit_scatter.py.  The decode engine's host planner
turns a block's token stream into per-output-byte immediate-source maps
(`kernels/ops.py` `decode_gather` builds them in XLA from the fixed-shape
`DevicePlan` arrays); this kernel resolves the transitive sources and
materializes the bytes:

    for each of `rounds` rounds:  ptr = ptr[ptr]      (pointer doubling)
    out[k] = block[lit_blk[ptr[k]]]                   (one final gather)

Doubling is a GLOBAL fixpoint iteration — round r reads positions written
conceptually by round r-1 at arbitrary indices — so the pointer table stays
fully VMEM-resident (256 KB at the 64 KB block size, the paper's on-chip
buffer scale) and the grid is a single step; parallelism comes from the
vmapped block axis of the micro-batch, not from tiling within a block.
`rounds` is static: the decode engine compiles one variant per power-of-two
depth bucket, worst case ceil(log2(MAX_BLOCK)) = 16, so even pathological
RLE chains (depth 65535) resolve with no data-dependent control flow and no
host fallback.

The gathers are `jnp.take`, which Mosaic lowers to the TPU dynamic-gather
unit (v4+); validated with interpret=True here.  The byte math is
intentionally duplicated from kernels/ref.py `decode_gather_ref` (the jnp
oracle): the two paths stay independent and are asserted bit-identical in
tests/test_device_decode.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_wave_kernel(total_ref, blk_ref, lit_blk_ref, ptr_ref, out_ref, *,
                        rounds):
    k = jax.lax.iota(jnp.int32, out_ref.shape[0])
    p = ptr_ref[...]
    for _ in range(rounds):
        p = jnp.take(p, p)
    b = jnp.take(blk_ref[...], jnp.take(lit_blk_ref[...], p))
    out_ref[...] = jnp.where(k < total_ref[0], b, 0)


@functools.partial(jax.jit, static_argnames=("rounds", "interpret"))
def decode_wave_pallas(block, lit_blk, ptr, total, rounds: int,
                       interpret: bool = True):
    """Resolve + materialize one block's decoded bytes on device.

    block   : (B,) int32 compressed-payload byte values (zero-padded)
    lit_blk : (K,) int32 literal source index per output byte
    ptr     : (K,) int32 immediate source position per output byte
    total   : (1,) int32 decoded size; positions >= total emit 0
    rounds  : static pointer-doubling round count (resolves depth 2^rounds)

    Returns (K,) int32 byte values (cast to uint8 at the ops.py boundary —
    int32 lanes keep the kernel on the VPU's native element type).
    """
    K = ptr.shape[0]
    B = block.shape[0]
    assert lit_blk.shape[0] == K, (lit_blk.shape, K)
    return pl.pallas_call(
        functools.partial(_decode_wave_kernel, rounds=rounds),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),   # total: scalar-as-(1,)
            pl.BlockSpec((B,), lambda i: (0,)),   # full compressed block
            pl.BlockSpec((K,), lambda i: (0,)),   # literal source map
            pl.BlockSpec((K,), lambda i: (0,)),   # immediate pointer map
        ],
        out_specs=pl.BlockSpec((K,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((K,), jnp.int32),
        interpret=interpret,
    )(total, block, lit_blk, ptr)
