"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lz4_types import HASH_PRIME, MIN_MATCH, LAST_LITERALS


def fibhash_ref(b0, b1, b2, b3, hash_bits: int):
    """Fibonacci hash of the little-endian 4-byte word at each position.

    b0..b3 are the byte streams shifted by 0..3 positions (int32 in [0,255]).
    Returns (word_u32_as_i32, hash) — hash in [0, 2^hash_bits).
    """
    w = (
        b0.astype(jnp.uint32)
        | (b1.astype(jnp.uint32) << 8)
        | (b2.astype(jnp.uint32) << 16)
        | (b3.astype(jnp.uint32) << 24)
    )
    h = (w * jnp.uint32(HASH_PRIME)) >> jnp.uint32(32 - hash_bits)
    return w.astype(jnp.int32), h.astype(jnp.int32)


def match_extend_ref(block, cand, valid, n, max_match: int):
    """Bounded extended-match length (the paper's feedforward S2 datapath).

    block : (B,) int32 byte values (padded past `n` arbitrarily)
    cand  : (P,) int32 candidate position for each position p (garbage if ~valid)
    valid : (P,) bool  4-byte match already confirmed at p
    n     : scalar int32, true block length
    max_match : static python int, the match-length cap (paper: 36)

    Returns (P,) int32 full match length (>= 4 where valid, 0 elsewhere),
    capped at max_match and at the end-of-block rule (match end <= n-5).
    """
    P = cand.shape[0]
    p = jnp.arange(P, dtype=jnp.int32)
    max_extra = jnp.clip(n - LAST_LITERALS - (p + MIN_MATCH), 0, max_match - MIN_MATCH)
    prefix = jnp.ones(P, dtype=bool)
    length = jnp.zeros(P, dtype=jnp.int32)
    for j in range(max_match - MIN_MATCH):
        cur = block[jnp.clip(p + MIN_MATCH + j, 0, block.shape[0] - 1)]
        cnd = block[jnp.clip(cand + MIN_MATCH + j, 0, block.shape[0] - 1)]
        prefix = prefix & (cur == cnd) & (j < max_extra)
        length = length + prefix.astype(jnp.int32)
    return jnp.where(valid, MIN_MATCH + length, 0)
