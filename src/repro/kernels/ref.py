"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lz4_types import HASH_PRIME, MF_LIMIT, MIN_MATCH, LAST_LITERALS

# Row layout of the per-sequence `fields` array consumed by the emit kernels
# (`emit_bytes_ref` here, `emit_scatter.py` on the Pallas path).  One column
# per sequence: the W per-window sequences plus the final literals-only one.
F_START = 0       # output byte offset of the sequence's token
F_ANCHOR = 1      # input offset of the sequence's first literal
F_LIT = 2         # literal count
F_LIT_EXT = 3     # literal-length extension byte count
F_MLX = 4         # match length - MIN_MATCH (0 for the final sequence)
F_MATCH_EXT = 5   # match-length extension byte count
F_OFF = 6         # 16-bit match back-offset (0 for the final sequence)
F_HAS_MATCH = 7   # 1 where the sequence carries a match, 0 for the final one
N_FIELDS = 8


def fibhash_ref(b0, b1, b2, b3, hash_bits: int):
    """Fibonacci hash of the little-endian 4-byte word at each position.

    b0..b3 are the byte streams shifted by 0..3 positions (int32 in [0,255]).
    Returns (word_u32_as_i32, hash) — hash in [0, 2^hash_bits).
    """
    w = (
        b0.astype(jnp.uint32)
        | (b1.astype(jnp.uint32) << 8)
        | (b2.astype(jnp.uint32) << 16)
        | (b3.astype(jnp.uint32) << 24)
    )
    h = (w * jnp.uint32(HASH_PRIME)) >> jnp.uint32(32 - hash_bits)
    return w.astype(jnp.int32), h.astype(jnp.int32)


def match_extend_ref(block, cand, valid, n, max_match: int):
    """Bounded extended-match length (the paper's feedforward S2 datapath).

    block : (B,) int32 byte values (padded past `n` arbitrarily)
    cand  : (P,) int32 candidate position for each position p (garbage if ~valid)
    valid : (P,) bool  4-byte match already confirmed at p
    n     : scalar int32, true block length
    max_match : static python int, the match-length cap (paper: 36)

    Returns (P,) int32 full match length (>= 4 where valid, 0 elsewhere),
    capped at max_match and at the end-of-block rule (match end <= n-5).
    """
    P = cand.shape[0]
    p = jnp.arange(P, dtype=jnp.int32)
    max_extra = jnp.clip(n - LAST_LITERALS - (p + MIN_MATCH), 0, max_match - MIN_MATCH)
    prefix = jnp.ones(P, dtype=bool)
    length = jnp.zeros(P, dtype=jnp.int32)
    for j in range(max_match - MIN_MATCH):
        cur = block[jnp.clip(p + MIN_MATCH + j, 0, block.shape[0] - 1)]
        cnd = block[jnp.clip(cand + MIN_MATCH + j, 0, block.shape[0] - 1)]
        prefix = prefix & (cur == cnd) & (j < max_extra)
        length = length + prefix.astype(jnp.int32)
    return jnp.where(valid, MIN_MATCH + length, 0)


def scatter_candidates_ref(hashes, n, hash_bits: int, pws: int):
    """Scatter-max LVT candidate resolution (no sort).

    cand(p) = max{q : hash(q)=hash(p), win(q)<win(p)}: scatter positions
    into a (windows x entries) grid — the hash table materialized over
    time — exclusive cummax along the window axis (log-depth), gather at
    (win(p), hash(p)).  The single source of this formulation, shared by
    `fused_ref` below and `jax_compressor._candidates_scatter`
    (candidate_impl="scatter"), so the twin and the staged impl cannot
    drift.  Returns (P,) int32, -1 where no candidate/invalid position.
    """
    import jax

    P = hashes.shape[0]
    E = 1 << hash_bits
    p = jnp.arange(P, dtype=jnp.int32)
    valid_pos = p <= n - MIN_MATCH
    W = P // pws
    win = p // pws
    key = jnp.where(valid_pos, win * E + hashes, W * E)  # sentinel row dropped
    table = jnp.zeros((W * E + 1,), jnp.int32).at[key].max(p + 1, mode="drop")
    tm = table[: W * E].reshape(W, E)
    run_max = jax.lax.associative_scan(jnp.maximum, tm, axis=0)
    excl = jnp.concatenate([jnp.zeros((1, E), jnp.int32), run_max[:-1]], axis=0)
    cand = excl[win, jnp.clip(hashes, 0, E - 1)] - 1
    return jnp.where(valid_pos, cand, -1)


def fused_ref(block, n, positions: int, hash_bits: int, pws: int,
              max_match: int):
    """jnp twin of the fused compression datapath (fused_compress.py).

    One expression of hash -> LVT candidate -> word compare -> bounded
    extension, with candidate resolution in the scatter-max formulation
    (NO sort): scatter positions into a (windows x entries) grid — the
    hash table materialized over time — exclusive cummax along the window
    axis, gather at (win(p), hash(p)).  Pinned bit-identical to the
    `_candidates` sort oracle at the match-record level, and elementwise
    equal to the Pallas kernel's (cand, lengths) outputs
    (tests/test_fused_compress.py).

    block     : (B,) int32 byte values, zeroed past `n`; B >= positions +
                max_match (the padded compressor block)
    n         : scalar int32 true block length
    positions : static position count P (P % pws == 0)

    Returns ``(cand, lengths)``: (P,) int32 candidate position (-1 where
    none/invalid) and full match length (0 where no valid match).
    """
    P = positions
    b0 = block[:P]
    b1 = block[1 : P + 1]
    b2 = block[2 : P + 2]
    b3 = block[3 : P + 3]
    words, hashes = fibhash_ref(b0, b1, b2, b3, hash_bits)
    p = jnp.arange(P, dtype=jnp.int32)
    cand = scatter_candidates_ref(hashes, n, hash_bits, pws)
    wc = jnp.take(words, jnp.clip(cand, 0, P - 1))
    valid4 = (cand >= 0) & (wc == words) & (p <= n - MF_LIMIT)
    lengths = match_extend_ref(block, cand, valid4, n, max_match)
    return cand, lengths


def emit_bytes_ref(block, seg, fields, total):
    """LZ4 byte materialization: (output position -> byte) via gathers.

    The inverse-scatter formulation of block emission: instead of scattering
    each sequence's ragged pieces into the output (variable-length writes),
    every output position k looks up its covering sequence `seg[k]` and
    derives its byte from the relative offset r = k - start alone:

        r == 0                         -> token
        1 <= r <= lit_ext              -> literal-length extension byte
        lit_ext < r <= lit_ext + lit   -> literal (one gather from the input)
        r == 1 + lit_ext + lit         -> offset low byte
        r == 2 + lit_ext + lit         -> offset high byte
        r beyond                       -> match-length extension byte

    block  : (B,) int32 byte values of the input block (zeroed past n)
    seg    : (K,) int32 covering-sequence index per output position
    fields : (N_FIELDS, S) int32 per-sequence layout (see F_* rows above)
    total  : scalar int32 exact compressed size; positions >= total emit 0

    Returns (K,) uint8.  Bit-identical to `repro.core.emitter.emit_block`
    (asserted in tests/test_device_emit.py) — purely elementwise once the
    per-sequence fields are gathered, which is what makes it a kernel shape.
    """
    K = seg.shape[0]
    k = jnp.arange(K, dtype=jnp.int32)
    st = jnp.take(fields[F_START], seg)
    anc = jnp.take(fields[F_ANCHOR], seg)
    lit = jnp.take(fields[F_LIT], seg)
    le = jnp.take(fields[F_LIT_EXT], seg)
    mlx = jnp.take(fields[F_MLX], seg)
    me = jnp.take(fields[F_MATCH_EXT], seg)
    off = jnp.take(fields[F_OFF], seg)
    hm = jnp.take(fields[F_HAS_MATCH], seg)

    r = k - st
    token = (jnp.minimum(lit, 15) << 4) | jnp.where(hm > 0, jnp.minimum(mlx, 15), 0)
    # Extension runs are (count-1) bytes of 255 followed by (value-15) % 255.
    lit_ext_byte = jnp.where(r < le, 255, (lit - 15) % 255)
    src = jnp.clip(anc + r - 1 - le, 0, block.shape[0] - 1)
    lit_byte = jnp.take(block, src)
    lit_end = 1 + le + lit
    mext_byte = jnp.where(r - (lit_end + 2) < me - 1, 255, (mlx - 15) % 255)
    b = jnp.where(r == 0, token,
        jnp.where(r <= le, lit_ext_byte,
        jnp.where(r <= le + lit, lit_byte,
        jnp.where(r == lit_end, off & 0xFF,
        jnp.where(r == lit_end + 1, (off >> 8) & 0xFF, mext_byte)))))
    return jnp.where(k < total, b, 0).astype(jnp.uint8)


def plan_fields_ref(block, n, chain_rounds: int = 16):
    """jnp twin of the speculative parse kernel (plan_speculative.py).

    Decode a CANDIDATE sequence header at EVERY byte offset of a compressed
    block — token nibbles, 0xFF-run literal/match length extensions, the
    16-bit back offset, the next-header position — then select the single
    chain actually reachable from offset 0.  This is the feedback-free
    formulation of `plan_block_fast`'s prepass (Sitaridi et al., arXiv
    1606.00519): every field is a pure function of its byte offset, so the
    serial parse's only residue — *which* offsets are headers — becomes a
    log-depth reachability pass over the next[] map (scatter-max union of
    the marked set through its 2^k-hop pointers, the decode-side mirror of
    `decode_gather_ref`'s pointer doubling).

    The field math reproduces `plan_block_fast` byte for byte, INCLUDING
    its clamped reads (terminator/offset bytes are fetched at
    ``min(pos, n-1)``), so candidate fields at non-header offsets — and the
    error flags of truncated headers — match what the host planner would
    compute, and the in-graph validator in `kernels.ops.plan_speculative`
    can reject malformed streams with identical error codes.

    block        : (B,) int32 byte values of the compressed payload,
                   zeroed past ``n``; B must be STRICTLY greater than any
                   n (the run table is read at index n)
    n            : scalar int32 true payload length
    chain_rounds : static doubling depth; 16 covers any chain in a 64 KB
                   block (headers are >= 3 bytes apart, so < 2^15 hops)

    Returns seven (B,) int32 arrays:
      is_start  — 1 where a sequence header actually starts
      lit_start — offset of the sequence's first literal byte
      lit_len   — literal run length
      ls_end    — offset just past the literals (= the offset field)
      off       — 16-bit back offset (clamped-garbage where truncated)
      mlen      — match length (garbage for the final sequence)
      flags     — bit 0: truncated literal-length extension,
                  bit 1: truncated match-length extension
    """
    import jax

    B = block.shape[0]
    idx = jnp.arange(B, dtype=jnp.int32)
    n = jnp.asarray(n, jnp.int32)
    inb = idx < n
    nm1 = jnp.maximum(n - 1, 0)

    # ffrun[i] = length of the 0xFF run starting at i (0 at/past n): the
    # first non-0xFF position at or after i, by a reversed cummin, minus i.
    next_notff = jax.lax.cummin(
        jnp.where((block == 255) & inb, B, idx), reverse=True)
    ffrun = next_notff - idx

    # Literal half of the header: nibble, extension run, extended length.
    lit_nib = block >> 4
    has_lx = lit_nib == 15
    r1 = jnp.take(ffrun, jnp.minimum(idx + 1, B - 1))
    term1 = idx + 1 + r1                    # extension terminator position
    t1b = jnp.take(block, jnp.minimum(term1, nm1))
    lit_len = jnp.where(has_lx, r1 * 255 + t1b + 15, lit_nib)
    lit_start = idx + 1 + jnp.where(has_lx, 1 + r1, 0)
    ls_end = lit_start + lit_len

    # Match half: offset bytes at ls_end, extension run after them.
    m_nib = block & 15
    has_mx = m_nib == 15
    o0 = jnp.minimum(ls_end, nm1)
    off = jnp.take(block, o0) | (jnp.take(block, jnp.minimum(o0 + 1, nm1)) << 8)
    r2 = jnp.take(ffrun, jnp.minimum(ls_end + 2, n))
    term2 = ls_end + 2 + r2
    t2b = jnp.take(block, jnp.minimum(term2, nm1))
    mlen = jnp.where(has_mx, r2 * 255 + t2b + 19, m_nib + 4)
    nxt = ls_end + 2 + jnp.where(has_mx, r2 + 1, 0)

    flags = (has_lx & (term1 >= n)).astype(jnp.int32) \
        | ((has_mx & (term2 >= n)).astype(jnp.int32) << 1)

    # Chain select: headers are >= 3 bytes, so next[] strictly advances and
    # every chain exits through the sentinel fixed point at n.  mark holds
    # the set reachable from 0 in < 2^k hops; each round unions in the
    # 2^k-hop successors (one scatter-max) and squares the pointer map.
    jump = jnp.where(inb, jnp.minimum(nxt, n), idx)
    mark = (idx == 0).astype(jnp.int32)
    for _ in range(chain_rounds):
        mark = mark.at[jump].max(mark, mode="drop")
        jump = jnp.take(jump, jump)
    is_start = jnp.where(inb, mark, 0)
    return is_start, lit_start, lit_len, ls_end, off, mlen, flags


def decode_gather_ref(block, lit_blk, ptr, total, rounds: int):
    """Device-side block decode: transitive-source resolve + ONE byte gather.

    The read-path mirror of `emit_bytes_ref`: instead of executing match
    copies in stream order (serial feedback through the output buffer),
    every output byte k carries its IMMEDIATE source — itself for literal
    bytes (a fixed point of the source map), ``k - offset`` for match
    bytes — and the transitive source is resolved by pointer doubling:
    after r rounds of ``ptr = ptr[ptr]`` every dependency chain of depth
    <= 2^r terminates at a literal byte.  `rounds` is static (the decode
    engine picks it from the micro-batch's plan depth, worst case
    ceil(log2(MAX_BLOCK)) = 16), so the whole decode is `rounds` + 2
    gathers with no data-dependent control flow — the shape GPULZ and
    Sitaridi et al. reach for massively-parallel decompression.

    block   : (B,) int32 byte values of the COMPRESSED block (zero-padded)
    lit_blk : (K,) int32 per-output-byte literal source index into `block`
              (valid where the byte's resolved pointer lands — i.e. at
              literal positions; arbitrary elsewhere)
    ptr     : (K,) int32 per-output-byte immediate source position
    total   : scalar int32 decoded size; positions >= total emit 0

    Returns (K,) uint8.  Bit-identical to `repro.core.decode_plan.
    execute_plan` / `execute_device_plan` (asserted in tests).
    """
    K = ptr.shape[0]
    k = jnp.arange(K, dtype=jnp.int32)
    for _ in range(rounds):
        ptr = jnp.take(ptr, ptr)
    b = jnp.take(block, jnp.take(lit_blk, ptr))
    return jnp.where(k < total, b, 0).astype(jnp.uint8)
