"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lz4_types import HASH_PRIME, MF_LIMIT, MIN_MATCH, LAST_LITERALS

# Row layout of the per-sequence `fields` array consumed by the emit kernels
# (`emit_bytes_ref` here, `emit_scatter.py` on the Pallas path).  One column
# per sequence: the W per-window sequences plus the final literals-only one.
F_START = 0       # output byte offset of the sequence's token
F_ANCHOR = 1      # input offset of the sequence's first literal
F_LIT = 2         # literal count
F_LIT_EXT = 3     # literal-length extension byte count
F_MLX = 4         # match length - MIN_MATCH (0 for the final sequence)
F_MATCH_EXT = 5   # match-length extension byte count
F_OFF = 6         # 16-bit match back-offset (0 for the final sequence)
F_HAS_MATCH = 7   # 1 where the sequence carries a match, 0 for the final one
N_FIELDS = 8


def fibhash_ref(b0, b1, b2, b3, hash_bits: int):
    """Fibonacci hash of the little-endian 4-byte word at each position.

    b0..b3 are the byte streams shifted by 0..3 positions (int32 in [0,255]).
    Returns (word_u32_as_i32, hash) — hash in [0, 2^hash_bits).
    """
    w = (
        b0.astype(jnp.uint32)
        | (b1.astype(jnp.uint32) << 8)
        | (b2.astype(jnp.uint32) << 16)
        | (b3.astype(jnp.uint32) << 24)
    )
    h = (w * jnp.uint32(HASH_PRIME)) >> jnp.uint32(32 - hash_bits)
    return w.astype(jnp.int32), h.astype(jnp.int32)


def match_extend_ref(block, cand, valid, n, max_match: int):
    """Bounded extended-match length (the paper's feedforward S2 datapath).

    block : (B,) int32 byte values (padded past `n` arbitrarily)
    cand  : (P,) int32 candidate position for each position p (garbage if ~valid)
    valid : (P,) bool  4-byte match already confirmed at p
    n     : scalar int32, true block length
    max_match : static python int, the match-length cap (paper: 36)

    Returns (P,) int32 full match length (>= 4 where valid, 0 elsewhere),
    capped at max_match and at the end-of-block rule (match end <= n-5).
    """
    P = cand.shape[0]
    p = jnp.arange(P, dtype=jnp.int32)
    max_extra = jnp.clip(n - LAST_LITERALS - (p + MIN_MATCH), 0, max_match - MIN_MATCH)
    prefix = jnp.ones(P, dtype=bool)
    length = jnp.zeros(P, dtype=jnp.int32)
    for j in range(max_match - MIN_MATCH):
        cur = block[jnp.clip(p + MIN_MATCH + j, 0, block.shape[0] - 1)]
        cnd = block[jnp.clip(cand + MIN_MATCH + j, 0, block.shape[0] - 1)]
        prefix = prefix & (cur == cnd) & (j < max_extra)
        length = length + prefix.astype(jnp.int32)
    return jnp.where(valid, MIN_MATCH + length, 0)


def scatter_candidates_ref(hashes, n, hash_bits: int, pws: int):
    """Scatter-max LVT candidate resolution (no sort).

    cand(p) = max{q : hash(q)=hash(p), win(q)<win(p)}: scatter positions
    into a (windows x entries) grid — the hash table materialized over
    time — exclusive cummax along the window axis (log-depth), gather at
    (win(p), hash(p)).  The single source of this formulation, shared by
    `fused_ref` below and `jax_compressor._candidates_scatter`
    (candidate_impl="scatter"), so the twin and the staged impl cannot
    drift.  Returns (P,) int32, -1 where no candidate/invalid position.
    """
    import jax

    P = hashes.shape[0]
    E = 1 << hash_bits
    p = jnp.arange(P, dtype=jnp.int32)
    valid_pos = p <= n - MIN_MATCH
    W = P // pws
    win = p // pws
    key = jnp.where(valid_pos, win * E + hashes, W * E)  # sentinel row dropped
    table = jnp.zeros((W * E + 1,), jnp.int32).at[key].max(p + 1, mode="drop")
    tm = table[: W * E].reshape(W, E)
    run_max = jax.lax.associative_scan(jnp.maximum, tm, axis=0)
    excl = jnp.concatenate([jnp.zeros((1, E), jnp.int32), run_max[:-1]], axis=0)
    cand = excl[win, jnp.clip(hashes, 0, E - 1)] - 1
    return jnp.where(valid_pos, cand, -1)


def fused_ref(block, n, positions: int, hash_bits: int, pws: int,
              max_match: int):
    """jnp twin of the fused compression datapath (fused_compress.py).

    One expression of hash -> LVT candidate -> word compare -> bounded
    extension, with candidate resolution in the scatter-max formulation
    (NO sort): scatter positions into a (windows x entries) grid — the
    hash table materialized over time — exclusive cummax along the window
    axis, gather at (win(p), hash(p)).  Pinned bit-identical to the
    `_candidates` sort oracle at the match-record level, and elementwise
    equal to the Pallas kernel's (cand, lengths) outputs
    (tests/test_fused_compress.py).

    block     : (B,) int32 byte values, zeroed past `n`; B >= positions +
                max_match (the padded compressor block)
    n         : scalar int32 true block length
    positions : static position count P (P % pws == 0)

    Returns ``(cand, lengths)``: (P,) int32 candidate position (-1 where
    none/invalid) and full match length (0 where no valid match).
    """
    P = positions
    b0 = block[:P]
    b1 = block[1 : P + 1]
    b2 = block[2 : P + 2]
    b3 = block[3 : P + 3]
    words, hashes = fibhash_ref(b0, b1, b2, b3, hash_bits)
    p = jnp.arange(P, dtype=jnp.int32)
    cand = scatter_candidates_ref(hashes, n, hash_bits, pws)
    wc = jnp.take(words, jnp.clip(cand, 0, P - 1))
    valid4 = (cand >= 0) & (wc == words) & (p <= n - MF_LIMIT)
    lengths = match_extend_ref(block, cand, valid4, n, max_match)
    return cand, lengths


def emit_bytes_ref(block, seg, fields, total):
    """LZ4 byte materialization: (output position -> byte) via gathers.

    The inverse-scatter formulation of block emission: instead of scattering
    each sequence's ragged pieces into the output (variable-length writes),
    every output position k looks up its covering sequence `seg[k]` and
    derives its byte from the relative offset r = k - start alone:

        r == 0                         -> token
        1 <= r <= lit_ext              -> literal-length extension byte
        lit_ext < r <= lit_ext + lit   -> literal (one gather from the input)
        r == 1 + lit_ext + lit         -> offset low byte
        r == 2 + lit_ext + lit         -> offset high byte
        r beyond                       -> match-length extension byte

    block  : (B,) int32 byte values of the input block (zeroed past n)
    seg    : (K,) int32 covering-sequence index per output position
    fields : (N_FIELDS, S) int32 per-sequence layout (see F_* rows above)
    total  : scalar int32 exact compressed size; positions >= total emit 0

    Returns (K,) uint8.  Bit-identical to `repro.core.emitter.emit_block`
    (asserted in tests/test_device_emit.py) — purely elementwise once the
    per-sequence fields are gathered, which is what makes it a kernel shape.
    """
    K = seg.shape[0]
    k = jnp.arange(K, dtype=jnp.int32)
    st = jnp.take(fields[F_START], seg)
    anc = jnp.take(fields[F_ANCHOR], seg)
    lit = jnp.take(fields[F_LIT], seg)
    le = jnp.take(fields[F_LIT_EXT], seg)
    mlx = jnp.take(fields[F_MLX], seg)
    me = jnp.take(fields[F_MATCH_EXT], seg)
    off = jnp.take(fields[F_OFF], seg)
    hm = jnp.take(fields[F_HAS_MATCH], seg)

    r = k - st
    token = (jnp.minimum(lit, 15) << 4) | jnp.where(hm > 0, jnp.minimum(mlx, 15), 0)
    # Extension runs are (count-1) bytes of 255 followed by (value-15) % 255.
    lit_ext_byte = jnp.where(r < le, 255, (lit - 15) % 255)
    src = jnp.clip(anc + r - 1 - le, 0, block.shape[0] - 1)
    lit_byte = jnp.take(block, src)
    lit_end = 1 + le + lit
    mext_byte = jnp.where(r - (lit_end + 2) < me - 1, 255, (mlx - 15) % 255)
    b = jnp.where(r == 0, token,
        jnp.where(r <= le, lit_ext_byte,
        jnp.where(r <= le + lit, lit_byte,
        jnp.where(r == lit_end, off & 0xFF,
        jnp.where(r == lit_end + 1, (off >> 8) & 0xFF, mext_byte)))))
    return jnp.where(k < total, b, 0).astype(jnp.uint8)


def decode_gather_ref(block, lit_blk, ptr, total, rounds: int):
    """Device-side block decode: transitive-source resolve + ONE byte gather.

    The read-path mirror of `emit_bytes_ref`: instead of executing match
    copies in stream order (serial feedback through the output buffer),
    every output byte k carries its IMMEDIATE source — itself for literal
    bytes (a fixed point of the source map), ``k - offset`` for match
    bytes — and the transitive source is resolved by pointer doubling:
    after r rounds of ``ptr = ptr[ptr]`` every dependency chain of depth
    <= 2^r terminates at a literal byte.  `rounds` is static (the decode
    engine picks it from the micro-batch's plan depth, worst case
    ceil(log2(MAX_BLOCK)) = 16), so the whole decode is `rounds` + 2
    gathers with no data-dependent control flow — the shape GPULZ and
    Sitaridi et al. reach for massively-parallel decompression.

    block   : (B,) int32 byte values of the COMPRESSED block (zero-padded)
    lit_blk : (K,) int32 per-output-byte literal source index into `block`
              (valid where the byte's resolved pointer lands — i.e. at
              literal positions; arbitrary elsewhere)
    ptr     : (K,) int32 per-output-byte immediate source position
    total   : scalar int32 decoded size; positions >= total emit 0

    Returns (K,) uint8.  Bit-identical to `repro.core.decode_plan.
    execute_plan` / `execute_device_plan` (asserted in tests).
    """
    K = ptr.shape[0]
    k = jnp.arange(K, dtype=jnp.int32)
    for _ in range(rounds):
        ptr = jnp.take(ptr, ptr)
    b = jnp.take(block, jnp.take(lit_blk, ptr))
    return jnp.where(k < total, b, 0).astype(jnp.uint8)
