"""AdamW + LR schedules (cosine, WSD) — minimal, fully sharded-state friendly.

Optimizer state mirrors the parameter tree (same shapes/shardings), so FSDP
parameter sharding automatically gives ZeRO-style sharded optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # "cosine" | "wsd" | "constant"
    decay_frac: float = 0.1   # WSD: fraction of steps spent decaying


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
    if cfg.schedule == "wsd":  # MiniCPM warmup-stable-decay
        decay_start = cfg.total_steps * (1 - cfg.decay_frac)
        t = jnp.clip((step - decay_start) / max(cfg.total_steps - decay_start, 1), 0, 1)
        return cfg.lr * warm * jnp.exp(jnp.log(0.01) * t)  # exp decay to 1% of lr
    raise ValueError(cfg.schedule)


def init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * upd.astype(p.dtype)).astype(p.dtype), m2, v2

    out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
