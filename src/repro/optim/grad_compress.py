"""Cross-pod gradient compression: int8 quantization + error feedback.

In-jit entropy coding is not expressible inside an XLA collective (LZ4's
variable-length output has data-dependent shape), so the wire format for the
cross-pod gradient reduction is *fixed-rate* int8 with per-tensor scales +
error feedback (residual carried to the next step).  The LZ4 engine applies
at the host boundary instead (checkpoints, data shards, KV offload).

Two pieces:
  * quantize_with_error_feedback — pure function used inside train_step;
    tests verify convergence parity with fp32 gradients.
  * compressed_psum_pod — opt-in shard_map demonstration of an int8 psum over
    the "pod" axis (quantize -> psum int32 -> dequantize), the collective a
    1000-node fleet would run between pods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import get_mesh, shard_map_compat as _shard_map_compat


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def quantize_with_error_feedback(grads, ef):
    """int8-quantize each gradient tensor; the residual goes into `ef`."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(leaf, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def compressed_psum_pod(x):
    """int8 all-reduce over the "pod" mesh axis (shard_map demonstration).

    x must be replicated over "pod" axis-sharded inputs; returns the pod-sum
    computed through an int8 wire format: 4x less ICI traffic than f32.
    """
    mesh = get_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return x

    def local(v):
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, "pod")  # shared scale across pods
        q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, "pod")
        return total.astype(jnp.float32) * scale

    rest = tuple(a for a in mesh.axis_names if a != "pod")
    return _shard_map_compat()(
        local, mesh=mesh,
        in_specs=P(*((rest[0] if rest else None,) + (None,) * (x.ndim - 1))),
        out_specs=P(*((rest[0] if rest else None,) + (None,) * (x.ndim - 1))),
        check_vma=False,
    )(x)
