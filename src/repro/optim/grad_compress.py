"""Cross-pod gradient compression: int8 quantization + error feedback.

In-jit entropy coding is not expressible inside an XLA collective (LZ4's
variable-length output has data-dependent shape), so the wire format for the
cross-pod gradient reduction is *fixed-rate* int8 with per-tensor scales +
error feedback (residual carried to the next step).  The LZ4 engine applies
at the host boundary instead (checkpoints, data shards, KV offload).

Three pieces:
  * quantize_with_error_feedback — pure function used inside train_step;
    tests verify convergence parity with fp32 gradients.
  * compressed_psum_pod — opt-in shard_map demonstration of an int8 psum over
    the "pod" axis (quantize -> psum int32 -> dequantize), the collective a
    1000-node fleet would run between pods.
  * export_gradient_frame / import_gradient_frame — the host-boundary hook:
    a gradient pytree flattened to one byte stream and compressed through an
    `LZ4Engine` (a SHARDED engine fans the block stack across the mesh
    fabric and writes a seekable frame-v4 container) for cross-host
    shipping, gradient logging, or straggler replay.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import get_mesh, shard_map_compat as _shard_map_compat


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def quantize_with_error_feedback(grads, ef):
    """int8-quantize each gradient tensor; the residual goes into `ef`."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(leaf, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def compressed_psum_pod(x):
    """int8 all-reduce over the "pod" mesh axis (shard_map demonstration).

    x must be replicated over "pod" axis-sharded inputs; returns the pod-sum
    computed through an int8 wire format: 4x less ICI traffic than f32.
    """
    mesh = get_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return x

    def local(v):
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, "pod")  # shared scale across pods
        q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, "pod")
        return total.astype(jnp.float32) * scale

    rest = tuple(a for a in mesh.axis_names if a != "pod")
    return _shard_map_compat()(
        local, mesh=mesh,
        in_specs=P(*((rest[0] if rest else None,) + (None,) * (x.ndim - 1))),
        out_specs=P(*((rest[0] if rest else None,) + (None,) * (x.ndim - 1))),
        check_vma=False,
    )(x)


def export_gradient_frame(grads, engine=None) -> bytes:
    """Flatten a gradient pytree into ONE compressed frame (host boundary).

    Leaves are device_get'd in deterministic tree order and concatenated
    into a single byte stream, then compressed in one engine call so every
    block rides the micro-batched (or, with ``LZ4Engine(mesh=...)``,
    mesh-sharded) datapath.  The result is a self-describing LZ4R frame —
    v4 with a sharded engine — that `import_gradient_frame` restores
    against a matching pytree; block CRCs make in-flight corruption of a
    shipped gradient loud instead of silently diverging a replica.
    """
    from repro.core.engine import default_engine

    leaves = jax.tree.leaves(grads)
    raw = b"".join(np.asarray(jax.device_get(g)).tobytes() for g in leaves)
    return (engine or default_engine()).compress(raw)


def import_gradient_frame(frame: bytes, like):
    """Inverse of `export_gradient_frame`: frame -> pytree shaped like
    ``like`` (shapes/dtypes taken from its leaves; any frame version
    decodes, so sharded producers and unsharded consumers interoperate)."""
    from repro.core.frame import decode_frame

    raw = decode_frame(frame)
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for leaf in leaves:
        a = np.asarray(leaf)
        nb = a.dtype.itemsize * a.size
        out.append(np.frombuffer(raw[off: off + nb],
                                 dtype=a.dtype).reshape(a.shape))
        off += nb
    if off != len(raw):
        raise ValueError(
            f"frame holds {len(raw)} bytes, pytree expects {off}")
    return jax.tree.unflatten(treedef, out)
