"""Serve a small model with batched requests + LZ4 KV-cache offload.

  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs.base import get_config
from repro.distributed.sharding import single_device_mesh, use_mesh
from repro.models import lm
from repro.serving.engine import Request, ServingEngine, offload_cache, restore_cache

if __name__ == "__main__":
    cfg = get_config("gemma2-9b").reduced()
    rng = np.random.default_rng(0)
    with use_mesh(single_device_mesh()):
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(cfg, params, max_batch=4, cache_len=128)
        for uid in range(6):
            engine.add_request(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(5, 20))).tolist(),
                max_new_tokens=8,
            ))
        done = engine.run()
        for r in done:
            print(f"req {r.uid}: {len(r.prompt)} prompt tokens -> {r.output}")

        # pause a session: LZ4-offload its KV cache, restore bit-exactly
        batch = {"tokens": np.array([done[0].prompt + done[0].output], np.int32)}
        cache, _ = jax.jit(lm.prefill, static_argnums=(2, 3))(params, batch, cfg, 128)
        blob, stats = offload_cache(cache)
        restored = restore_cache(blob)
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(restored))
        )
        print(f"KV offload: {stats['raw']} -> {stats['compressed']} bytes "
              f"(ratio {stats['ratio']:.2f}), bit-exact restore: {ok}")
