"""End-to-end driver: train a ~100M-parameter qwen3-family model with the
full substrate stack — LZ4-compressed data shards, LZ4 checkpoints (async),
WSD/cosine schedule, failure-recovery drill, gradient compression.

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --quick    # tiny, 30 steps (CI)
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.quick:
        argv = [
            "--arch", "qwen3-1.7b", "--scale", "tiny",
            "--steps", str(args.steps or 30), "--batch", "4", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_train_quick", "--ckpt-every", "10",
            "--grad-compress", "--async-ckpt",
        ]
    else:
        argv = [
            "--arch", "qwen3-1.7b", "--scale", "100m",
            "--steps", str(args.steps or 200), "--batch", "8", "--seq", "256",
            "--ckpt-dir", "/tmp/repro_train_100m", "--ckpt-every", "50",
            "--simulate-failure", "60",  # prove recovery mid-run
            "--async-ckpt",
        ]
    sys.exit(0 if train_main(argv) else 0)
