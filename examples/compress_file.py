"""Compress any file with the LZ4-HT engine and verify the round trip.

  PYTHONPATH=src python examples/compress_file.py [path] [--entries 256]

Without a path, compresses the built-in corpus and prints per-file ratios
(the paper's Table III setting: combined scheme, 64 KB blocks).
"""
import argparse
import time

from repro.core import corpus_files, decode_block
from repro.core.jax_compressor import compress_bytes
from repro.core.lz4_types import MAX_BLOCK


def compress_report(name: str, data: bytes, hash_bits: int):
    t0 = time.perf_counter()
    blocks = compress_bytes(data, hash_bits=hash_bits)
    dt = time.perf_counter() - t0
    comp = sum(len(b) for b in blocks)
    restored = b"".join(decode_block(b) for b in blocks)
    assert restored == data, f"round-trip failed for {name}!"
    print(f"{name:>10}: {len(data):>8} -> {comp:>8} bytes "
          f"(ratio {len(data)/comp:5.3f}) {len(data)/dt/1e6:6.2f} MB/s  round-trip OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?")
    ap.add_argument("--entries", type=int, default=256)
    args = ap.parse_args()
    hb = args.entries.bit_length() - 1
    if args.path:
        with open(args.path, "rb") as f:
            data = f.read()
        compress_report(args.path, data, hb)
    else:
        for name, data in corpus_files().items():
            compress_report(name, data, hb)
