"""Compress any file with the batched LZ4Engine and verify the round trip.

  PYTHONPATH=src python examples/compress_file.py [path] [--entries 256] [--micro-batch 32]

Without a path, compresses the built-in corpus and prints per-file ratios
(the paper's Table III setting: combined scheme, 64 KB blocks).  Output is a
self-describing frame; the round trip goes through `decode_frame` with no
out-of-band lengths.
"""
import argparse
import time

from repro.core import LZ4Engine, corpus_files, decode_frame


def compress_report(engine: LZ4Engine, name: str, data: bytes):
    t0 = time.perf_counter()
    frame = engine.compress(data)
    dt = time.perf_counter() - t0
    restored = decode_frame(frame)
    assert restored == data, f"round-trip failed for {name}!"
    s = engine.stats
    print(f"{name:>10}: {len(data):>8} -> {len(frame):>8} bytes "
          f"(ratio {len(data)/max(len(frame), 1):5.3f}) {len(data)/dt/1e6:6.2f} MB/s "
          f"[{s.blocks} blocks / {s.dispatches} dispatches"
          f"{f', {s.raw_blocks} raw' if s.raw_blocks else ''}]  round-trip OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?")
    ap.add_argument("--entries", type=int, default=256)
    ap.add_argument("--micro-batch", type=int, default=32)
    args = ap.parse_args()
    engine = LZ4Engine(hash_bits=args.entries.bit_length() - 1,
                       micro_batch=args.micro_batch)
    if args.path:
        with open(args.path, "rb") as f:
            data = f.read()
        compress_report(engine, args.path, data)
    else:
        for name, data in corpus_files().items():
            compress_report(engine, name, data)
