"""Quickstart: the LZ4-HT engine in five minutes.

  PYTHONPATH=src python examples/quickstart.py

Covers: the batched `LZ4Engine` pipeline (one device dispatch per
micro-batch, device-resident byte emission, self-describing frame output),
the `device_emit` switch and what it saves in host transfer, the frame
round trip through `decode_frame`, the parallel decompression subsystem
(`LZ4DecodeEngine` + seekable `FrameReader` random access), comparing
schemes (the paper's Tables I-III in miniature), and the hardware cycle
model (Table IV).

Deeper dives: docs/architecture.md (pipeline map), docs/frame-format.md
(container spec), docs/tuning.md (parameter trade-offs).
"""
import numpy as np

from repro.core import (
    FrameReader,
    LZ4DecodeEngine,
    LZ4Engine,
    compress_greedy,
    compress_windowed,
    decode_block,
    decode_frame,
    encode_block,
    frame_info,
    plan_size,
)
from repro.core.cycle_model import ours_throughput

# --- some compressible data -------------------------------------------------
rng = np.random.default_rng(0)
data = (b"the quick brown fox jumps over the lazy dog. " * 800)[:32768]

# --- 1. the batched engine: frame in/out, one dispatch per micro-batch ------
engine = LZ4Engine()                     # paper's combined scheme (S1+S2)
frame = engine.compress(data)            # self-describing frame bytes
assert decode_frame(frame) == data       # no out-of-band lengths needed
info = frame_info(frame)
ratio = len(data) / len(frame)
print(f"LZ4Engine: ratio {ratio:.3f}, {info['block_count']} block(s), "
      f"{engine.stats.dispatches} dispatch(es), frame round-trip OK")

# --- 1b. device-side emission: only final bytes cross the host boundary ------
# By default (device_emit=True) the byte emission — prefix-sum offsets and
# the literal/token scatter — runs inside the jit graph, so the host fetches
# one padded byte buffer + size per block.  device_emit=False fetches the
# per-window match records instead and emits on host (the oracle path); the
# frames are bit-identical either way.  stats.host_bytes shows the saving.
host_engine = LZ4Engine(device_emit=False)
assert host_engine.compress(data) == frame
print(f"device_emit: host transfer {engine.stats.host_bytes} B "
      f"vs {host_engine.stats.host_bytes} B for the records path "
      f"({host_engine.stats.host_bytes / engine.stats.host_bytes:.2f}x), "
      f"frames bit-identical")

# --- 2. decompression: parallel decode + random access -----------------------
# decode_frame delegates to the LZ4DecodeEngine (two-phase plan/execute
# decode; blocks are independent, so an executor="process" engine fans them
# across cores).  The frame's block table doubles as a seek index:
# FrameReader.read_range decodes ONLY the 64 KB blocks covering a byte
# range — no full-frame decompress for partial reads.
big = (b"the quick brown fox jumps over the lazy dog. " * 8000)  # ~360 KB, 6 blocks
big_frame = LZ4Engine().compress(big)
reader = FrameReader(big_frame)
start, length = 200_000, 1_000
assert reader.read_range(start, length) == big[start:start + length]
assert reader.read_block(2) == big[reader.block_range(2)[0]:reader.block_range(2)[1]]
par = LZ4DecodeEngine(workers=2)           # executor="process" for multi-core
assert par.decode(big_frame) == big
blocks_touched = len(reader.blocks_for_range(start, length))
print(f"random access: read_range({start}, {length}) decoded "
      f"{blocks_touched}/{reader.block_count} blocks; parallel decode OK")
par.close()

# The device executor runs plan execution INSIDE jit (pointer-doubling
# source resolve, one vmapped dispatch per micro-batch); decode_to_device
# returns the restored bytes as a device array that never touched the host.
dev = LZ4DecodeEngine(executor="device")
assert dev.decode(big_frame) == big
arr = dev.decode_to_device(big_frame, verify=False)
assert bytes(memoryview(np.asarray(arr))) == big and dev.stats.host_bytes == 0
print(f"device decode: {dev.stats.device_blocks} blocks in "
      f"{dev.stats.dispatches} jit dispatches; device-resident restore "
      f"fetched {dev.stats.host_bytes} plaintext bytes to host")

# --- 3. scheme comparison (paper Tables I-III in miniature) ------------------
greedy = plan_size(compress_greedy(data, hash_bits=8))
single = plan_size(compress_windowed(data, hash_bits=8, max_match=None).sequences)
combined = plan_size(compress_windowed(data, hash_bits=8, max_match=36).sequences)
print(f"software LZ4 (multi-match) : {len(data)/greedy:.3f}")
print(f"single-match/window (S1)   : {len(data)/single:.3f}")
print(f"combined (S1+S2, cap 36)   : {len(data)/combined:.3f}")

# --- 4. why: deterministic hardware throughput (Table IV) --------------------
t = ours_throughput(len(data))
print(f"hardware model: {t.bytes_per_cycle:.3f} B/cycle -> "
      f"{list(t.gbps_at.values())[0]:.2f} Gb/s @ 251.57 MHz (paper: 16.10)")

# --- 5. golden-model equivalence ---------------------------------------------
res = compress_windowed(data, hash_bits=8, max_match=36)
blk = encode_block(data[:65536], res.sequences)
assert decode_block(blk) == data[:65536]
print("golden numpy model == exact LZ4 block format, decoder verified")
