#!/usr/bin/env python
"""Per-stage pipeline breakdown from a `repro.obs` telemetry bundle.

Reads the artifact directory `obs.dump_artifacts` (or
`benchmarks.common.dump_telemetry`) writes —

    trace.json     Chrome trace-event JSON (Perfetto-loadable)
    metrics.json   metrics registry snapshot
    events.jsonl   per-span JSONL log          (optional here)
    metrics.prom   Prometheus text exposition  (optional here)

— and prints the per-stage breakdown table: for every span name, the call
count, total/mean time, p50/p99 of the span durations, and share of the
traced wall clock.  This is the artifact BENCH entries and perf PRs embed:
`compress.dispatch` vs `compress.wait` vs `compress.drain` tells you
whether the write path is device-bound or drain-bound; `decode.plan` vs
`decode.execute` vs `decode.verify` does the same for the read path.

``--check`` schema-validates the bundle instead (CI runs this in both jax
matrix legs): trace.json must be Chrome trace-event shaped, metrics.json
must be a versioned registry snapshot.  Exit 0 iff valid.

Usage:
    python tools/trace_report.py experiments/telemetry/engine_batched
    python tools/trace_report.py <dir> --check
    python tools/trace_report.py <dir> --json       # breakdown as JSON

Stdlib only.  See docs/observability.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REQUIRED_EVENT_KEYS = {"name", "ph", "pid", "tid"}


def load_bundle(path: str) -> tuple[dict, dict]:
    """(trace, metrics) from a bundle dir or a single trace.json path."""
    if os.path.isdir(path):
        trace_path = os.path.join(path, "trace.json")
        metrics_path = os.path.join(path, "metrics.json")
    else:
        trace_path = path
        metrics_path = os.path.join(os.path.dirname(path), "metrics.json")
    with open(trace_path) as f:
        trace = json.load(f)
    metrics = {}
    if os.path.exists(metrics_path):
        with open(metrics_path) as f:
            metrics = json.load(f)
    return trace, metrics


# ---------------------------------------------------------------------------
# --check: schema validation
# ---------------------------------------------------------------------------

def check_trace(trace) -> list[str]:
    errors = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["trace.json: not a Chrome trace-event object "
                "(missing 'traceEvents')"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["trace.json: 'traceEvents' is not a list"]
    n_complete = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or not REQUIRED_EVENT_KEYS <= ev.keys():
            errors.append(f"trace.json: event {i} missing keys "
                          f"{sorted(REQUIRED_EVENT_KEYS - set(ev))}")
            continue
        if ev["ph"] == "X":
            n_complete += 1
            for k in ("ts", "dur"):
                if not isinstance(ev.get(k), (int, float)):
                    errors.append(
                        f"trace.json: complete event {i} ({ev['name']!r}) "
                        f"has non-numeric {k!r}")
            if isinstance(ev.get("dur"), (int, float)) and ev["dur"] < 0:
                errors.append(f"trace.json: event {i} has negative dur")
    if n_complete == 0:
        errors.append("trace.json: no complete ('ph': 'X') span events — "
                      "was the producer run with REPRO_OBS=1?")
    return errors


def check_metrics(metrics) -> list[str]:
    if not metrics:
        return ["metrics.json: missing or empty"]
    errors = []
    if not isinstance(metrics.get("schema_version"), int):
        errors.append("metrics.json: missing integer 'schema_version'")
    m = metrics.get("metrics")
    if not isinstance(m, dict):
        return errors + ["metrics.json: missing 'metrics' object"]
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(m.get(section), dict):
            errors.append(f"metrics.json: metrics.{section} is not an object")
    for name, h in (m.get("histograms") or {}).items():
        if not isinstance(h, dict) or "count" not in h or "buckets" not in h:
            errors.append(f"metrics.json: histogram {name!r} missing "
                          "count/buckets")
    return errors


# ---------------------------------------------------------------------------
# breakdown
# ---------------------------------------------------------------------------

def _pct(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list."""
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[int(k)]


def breakdown(trace: dict) -> dict:
    """Group complete events by span name -> timing summary (ms)."""
    spans: dict[str, list[float]] = {}
    t_min, t_max = float("inf"), float("-inf")
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        spans.setdefault(ev["name"], []).append(ev["dur"] / 1e3)
        t_min = min(t_min, ev["ts"])
        t_max = max(t_max, ev["ts"] + ev["dur"])
    wall_ms = (t_max - t_min) / 1e3 if spans else 0.0
    stages = {}
    for name, durs in spans.items():
        durs.sort()
        total = sum(durs)
        stages[name] = {
            "count": len(durs),
            "total_ms": round(total, 3),
            "mean_ms": round(total / len(durs), 4),
            "p50_ms": round(_pct(durs, 0.50), 4),
            "p99_ms": round(_pct(durs, 0.99), 4),
            "max_ms": round(durs[-1], 4),
            "pct_of_wall": round(100 * total / wall_ms, 1) if wall_ms else 0.0,
        }
    return {
        "wall_ms": round(wall_ms, 3),
        "dropped_events": trace.get("otherData", {}).get("dropped_events", 0),
        "stages": dict(sorted(stages.items(),
                              key=lambda kv: -kv[1]["total_ms"])),
    }


def print_breakdown(b: dict, metrics: dict) -> None:
    head = (f"{'stage':<26} {'count':>7} {'total ms':>10} {'mean ms':>9} "
            f"{'p50 ms':>9} {'p99 ms':>9} {'% wall':>7}")
    print(f"traced wall clock: {b['wall_ms']:.1f} ms"
          + (f"  (DROPPED {b['dropped_events']} events)"
             if b["dropped_events"] else ""))
    print(head)
    print("-" * len(head))
    for name, s in b["stages"].items():
        print(f"{name:<26} {s['count']:>7} {s['total_ms']:>10.1f} "
              f"{s['mean_ms']:>9.3f} {s['p50_ms']:>9.3f} {s['p99_ms']:>9.3f} "
              f"{s['pct_of_wall']:>6.1f}%")
    m = metrics.get("metrics") or {}
    counters = m.get("counters") or {}
    if counters:
        print()
        print(f"{'counter':<34} {'value':>14}")
        print("-" * 49)
        for name, v in sorted(counters.items()):
            print(f"{name:<34} {v:>14}")
    gauges = m.get("gauges") or {}
    if gauges:
        print()
        print(f"{'gauge':<34} {'value':>14}")
        print("-" * 49)
        for name, v in sorted(gauges.items()):
            print(f"{name:<34} {v:>14}")
    hists = m.get("histograms") or {}
    if hists:
        print()
        print(f"{'histogram':<30} {'count':>7} {'p50':>12} {'p90':>12} "
              f"{'p99':>12}")
        print("-" * 76)
        for name, h in sorted(hists.items()):
            def fmt(x):
                return "-" if x is None else f"{x:.6g}"
            print(f"{name:<30} {h['count']:>7} {fmt(h.get('p50')):>12} "
                  f"{fmt(h.get('p90')):>12} {fmt(h.get('p99')):>12}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bundle", help="telemetry bundle dir (or trace.json path)")
    ap.add_argument("--check", action="store_true",
                    help="schema-validate the bundle and exit")
    ap.add_argument("--json", action="store_true",
                    help="print the breakdown as JSON instead of a table")
    args = ap.parse_args(argv)

    try:
        trace, metrics = load_bundle(args.bundle)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot load bundle: {e}", file=sys.stderr)
        return 1

    if args.check:
        errors = check_trace(trace) + check_metrics(metrics)
        if errors:
            print(f"FAIL: {len(errors)} schema problem(s):", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        n = sum(1 for ev in trace["traceEvents"] if ev.get("ph") == "X")
        print(f"OK: {n} span events, "
              f"{len((metrics.get('metrics') or {}).get('counters') or {})} "
              "counters — bundle is schema-valid")
        return 0

    b = breakdown(trace)
    if args.json:
        print(json.dumps({"breakdown": b,
                          "metrics": metrics.get("metrics")}, indent=1))
    else:
        print_breakdown(b, metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
