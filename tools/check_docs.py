#!/usr/bin/env python
"""Docs link checker: fail CI on dangling cross-references.

Checks, over every `docs/*.md` page:
  * markdown links `[text](target)` — relative targets must exist
    (resolved against the page's directory); `#anchor` fragments on
    markdown targets must match a heading's GitHub-style slug;
  * inline-code repo references — backtick spans that look like repo paths
    (`src/repro/core/frame.py`, optionally with a `:LINE` anchor) must
    exist, and the line anchor must not exceed the file's length (so code
    moves that invalidate docs anchors fail the build);

and, over every `src/**/*.py` and `tests/*.py`:
  * any `docs/<page>.md` mentioned in source (the module-docstring
    cross-links) must exist.

Exit status 0 iff everything resolves. No dependencies beyond stdlib.

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`]+)`")
REPO_PATH = re.compile(
    r"^(?P<path>\.?[\w./-]+\.(?:py|md|json|yml|yaml|toml|txt))(?::(?P<line>\d+))?$"
)
DOC_MENTION = re.compile(r"docs/[\w-]+\.md")


def github_slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor slug (close enough for ASCII)."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(md: pathlib.Path) -> set[str]:
    slugs: set[str] = set()
    in_fence = False
    for line in md.read_text().splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence and line.startswith("#"):
            slugs.add(github_slug(line.lstrip("#")))
    return slugs


def strip_fences(text: str) -> str:
    """Drop fenced code blocks (their contents are examples, not refs)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_doc(md: pathlib.Path) -> list[str]:
    errors: list[str] = []
    text = strip_fences(md.read_text())

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if not dest.exists():
            errors.append(f"{md.relative_to(REPO)}: dangling link target {target!r}")
            continue
        if frag and dest.suffix == ".md":
            if frag not in heading_slugs(dest):
                errors.append(
                    f"{md.relative_to(REPO)}: anchor #{frag} not found in "
                    f"{dest.relative_to(REPO)}"
                )

    for m in CODE_SPAN.finditer(text):
        ref = REPO_PATH.match(m.group(1).strip())
        if not ref:
            continue
        dest = REPO / ref.group("path")
        if not dest.exists():
            errors.append(
                f"{md.relative_to(REPO)}: referenced file {ref.group('path')!r} "
                "does not exist"
            )
            continue
        if ref.group("line"):
            n_lines = len(dest.read_text().splitlines())
            line = int(ref.group("line"))
            if line > n_lines:
                errors.append(
                    f"{md.relative_to(REPO)}: {ref.group('path')}:{line} is past "
                    f"end of file ({n_lines} lines) — stale line anchor"
                )
    return errors


def check_source_mentions() -> list[str]:
    errors: list[str] = []
    for py in [*REPO.glob("src/**/*.py"), *REPO.glob("tests/*.py"),
               *REPO.glob("benchmarks/*.py"), *REPO.glob("examples/*.py")]:
        for mention in set(DOC_MENTION.findall(py.read_text())):
            if not (REPO / mention).exists():
                errors.append(
                    f"{py.relative_to(REPO)}: mentions {mention} which does not exist"
                )
    return errors


def main() -> int:
    pages = sorted(DOCS.glob("*.md"))
    if not pages:
        print("FAIL: docs/ contains no markdown pages", file=sys.stderr)
        return 1
    required = {"architecture.md", "frame-format.md", "tuning.md",
                "observability.md", "resilience.md"}
    missing = required - {p.name for p in pages}
    errors: list[str] = [f"docs/: required page {m} missing" for m in sorted(missing)]
    for md in pages:
        errors.extend(check_doc(md))
    errors.extend(check_source_mentions())
    if errors:
        print(f"FAIL: {len(errors)} dangling docs reference(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n_refs = sum(
        len(MD_LINK.findall(strip_fences(p.read_text())))
        + len(CODE_SPAN.findall(strip_fences(p.read_text())))
        for p in pages
    )
    print(f"OK: {len(pages)} docs page(s), ~{n_refs} references checked, "
          "no dangling links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
