"""Sharded compression fabric: weak scaling across fake device counts.

The fabric (src/repro/distributed/fabric.py) claims the block stack can be
partitioned over a mesh with per-shard output bytes IDENTICAL to a
single-device engine on the same slice.  This benchmark validates both
halves of that claim on CPU:

  * **weak scaling** — each device count N in {1, 2, 4, 8} compresses a
    corpus of N x BLOCKS_PER_SHARD blocks through a mesh of N fake devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``, so each sweep
    point runs in a fresh subprocess: the flag must be set before jax
    imports).  Under weak scaling the per-shard work is constant, so ideal
    behaviour is flat wall time / linearly growing throughput.  On CPU the
    "devices" all share the host's cores, so the curve mostly measures
    dispatch overhead — the numbers are a correctness-shaped baseline for
    real multi-chip runs, same caveat as device_emit (EXPERIMENTS.md).
  * **byte identity** — every sweep point asserts the mesh-path frame equals
    the host-partition oracle's frame, each shard's subframe equals a
    single-device engine run on that shard's slice, the v4 container
    round-trips through the serial oracle, and `read_range` spans crossing
    shard boundaries return the right bytes.

Writes experiments/benchmarks/sharded_fabric.json, mirrored to
BENCH_sharded_fabric.json at the repo root.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

if __package__ in (None, ""):        # `python benchmarks/sharded_fabric.py`
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import dump_telemetry, save_json
else:
    from .common import dump_telemetry, save_json

DEVICE_COUNTS = (1, 2, 4, 8)
BLOCKS_PER_SHARD = 2
REPEAT = 2

# Runs in a fresh interpreter per device count; prints one RESULT: JSON line.
_CHILD = r"""
import json
import os
import sys
import time

import numpy as np

from repro.core import FrameReader, LZ4Engine, decode_frame_serial, frame_info
from repro.core.lz4_types import MAX_BLOCK
from repro.distributed import fabric
from repro.distributed.sharding import make_mesh_compat

import jax

devices = int(os.environ["FABRIC_BENCH_DEVICES"])
blocks_per_shard = int(os.environ["FABRIC_BENCH_BPS"])
repeat = int(os.environ["FABRIC_BENCH_REPEAT"])
assert len(jax.devices()) == devices

n_blocks = devices * blocks_per_shard
rng = np.random.default_rng(7)
parts = []
for i in range(n_blocks):
    # 2/3 compressible structure, 1/3 incompressible per block
    parts.append((b"weak scaling shard %d " % i) * (2 * MAX_BLOCK // 63))
    parts.append(rng.integers(0, 256, MAX_BLOCK // 3, np.uint8).tobytes())
data = b"".join(parts)[: n_blocks * MAX_BLOCK]

mesh = make_mesh_compat((devices,), ("data",))
eng = LZ4Engine(mesh=mesh)
assert eng.shards == devices

frame = eng.compress(data)  # warmup (jit compile)
best = float("inf")
for _ in range(repeat):
    t0 = time.perf_counter()
    frame = eng.compress(data)
    best = min(best, time.perf_counter() - t0)

# -- byte-identity checks (the acceptance criteria, not just timing) --------
info = frame_info(frame)
assert info["version"] == 4 and info["shard_count"] == devices
oracle = LZ4Engine(shards=devices).compress(data)
identical_to_oracle = frame == oracle
single = LZ4Engine()
chunks = [data[i: i + MAX_BLOCK] for i in range(0, len(data), MAX_BLOCK)]
per_shard_identical = all(
    fabric.shard_subframe(frame, sl.shard) == single.compress(
        b"".join(chunks[sl.start: sl.stop]))
    for sl in fabric.partition_blocks(len(chunks), devices))
roundtrip_ok = decode_frame_serial(frame) == data
r = FrameReader(frame)
b = blocks_per_shard * MAX_BLOCK  # first shard boundary
cross_read_ok = (devices == 1 or
                 r.read_range(b - 64, 128) == data[b - 64: b + 64])

print("RESULT:" + json.dumps({
    "devices": devices,
    "blocks": n_blocks,
    "bytes_in": len(data),
    "frame_bytes": len(frame),
    "compress_s": round(best, 4),
    "compress_mb_s": round(len(data) / best / 1e6, 3),
    "dispatches": eng.stats.dispatches,
    "identical_to_host_oracle": identical_to_oracle,
    "per_shard_identical_to_single_device": per_shard_identical,
    "serial_roundtrip_ok": roundtrip_ok,
    "cross_shard_read_range_ok": cross_read_ok,
}))
"""


def _run_point(devices: int) -> dict:
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        "FABRIC_BENCH_DEVICES": str(devices),
        "FABRIC_BENCH_BPS": str(BLOCKS_PER_SHARD),
        "FABRIC_BENCH_REPEAT": str(REPEAT),
    })
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fabric bench child (devices={devices}) failed:\n"
            + proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def run() -> dict:
    points = []
    for devices in DEVICE_COUNTS:
        pt = _run_point(devices)
        for check in ("identical_to_host_oracle",
                      "per_shard_identical_to_single_device",
                      "serial_roundtrip_ok", "cross_shard_read_range_ok"):
            assert pt[check], f"devices={devices}: {check} failed"
        points.append(pt)
        print(f"[sharded_fabric] devices={devices} "
              f"blocks={pt['blocks']} {pt['compress_mb_s']} MB/s "
              f"({pt['dispatches']} dispatches)", flush=True)

    base = points[0]
    out = {
        "config": {
            "device_counts": list(DEVICE_COUNTS),
            "blocks_per_shard": BLOCKS_PER_SHARD,
            "repeat": REPEAT,
            "note": "fake CPU devices share the host's cores: the scaling "
                    "column measures dispatch overhead, the identity "
                    "columns are the real acceptance surface",
        },
        "weak_scaling": points,
        "summary": {
            "throughput_x_1_to_8": round(
                points[-1]["compress_mb_s"] / base["compress_mb_s"], 2),
            "all_frames_byte_identical_to_oracle": True,
            "all_per_shard_identical_to_single_device": True,
        },
    }
    save_json("sharded_fabric", out)
    root = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_sharded_fabric.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=1)
    # With REPRO_OBS=1 the parent process has no spans of its own (the work
    # runs in the sweep children) but the bundle still records the registry
    # state for trace_report's schema check.
    dump_telemetry("sharded_fabric")
    return out


if __name__ == "__main__":
    run()
