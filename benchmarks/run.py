"""Benchmark harness aggregator — one function per paper table.

Prints ``name,us_per_call,derived`` CSV lines; detailed JSON lands in
experiments/benchmarks/.  `--full` uses the whole corpus (slower).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="use the full corpus")
    args = ap.parse_args(argv)
    fast = not args.full

    from . import (
        engine_batched,
        jax_throughput,
        table1_window,
        table2_maxlen,
        table3_combined,
        table4_throughput,
    )

    jobs = [
        ("table1_single_vs_multi", table1_window.run,
         lambda r: f"attenuation {r['rows'][0]['attenuation_pct']}..{r['rows'][-1]['attenuation_pct']}% (paper 0.86..5.39)"),
        ("table2_maxlen_cap", table2_maxlen.run,
         lambda r: f"att@36 {min(r['attenuation_36_pct'])}..{max(r['attenuation_36_pct'])}% (paper 4.46..8.23) monotone={r['monotone_in_cap']}"),
        ("table3_combined", table3_combined.run,
         lambda r: f"attenuation {r['rows'][0]['attenuation_pct']}..{r['rows'][-1]['attenuation_pct']}% (paper 4.93..11.68)"),
        ("table4_throughput", table4_throughput.run,
         lambda r: f"ours {r['ours']['gbps']}Gb/s (paper 16.10) baseline {r['baseline_multi_match']['gbps']}Gb/s speedup {r['speedup_vs_baseline']}x (paper 2.648x)"),
        ("jax_engine_throughput", jax_throughput.run,
         lambda r: f"cpu {r['cpu_mbps_batch']}MB/s; v5e roofline {r['tpu_v5e_roofline_gbps_per_chip']}Gb/s/chip"),
        ("engine_batched", engine_batched.run,
         lambda r: f"serial {r['serial_blocks_per_s']} blk/s; best batched "
                   f"{r['speedup_best_vs_serial']}x"),
    ]
    print("name,us_per_call,derived")
    for name, fn, describe in jobs:
        t0 = time.perf_counter()
        result = fn(fast=fast)
        dt_us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{dt_us:.0f},{describe(result)}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
