"""Paper Table I: single-match-per-window vs multi-match compression ratio,
swept over hash-table sizes (64..8192), PWS=8, 64 KB blocks.

Claim reproduced: attenuation is small (sub-%-to-few-%) and GROWS with the
number of hash-table entries (more candidates -> more multi-match windows).
"""
from __future__ import annotations

from repro.core import compress_greedy, compress_windowed, plan_size

from .common import ENTRY_SWEEP, bits, corpus_ratio, corpus_subset, save_json


def run(fast: bool = True) -> dict:
    blocks = corpus_subset(fast)
    rows = []
    for entries in ENTRY_SWEEP:
        hb = bits(entries)
        multi = corpus_ratio(lambda b: plan_size(compress_greedy(b, hash_bits=hb)), blocks)
        single = corpus_ratio(
            lambda b: plan_size(compress_windowed(b, hash_bits=hb, max_match=None).sequences),
            blocks,
        )
        rows.append({
            "entries": entries,
            "multi_match": round(multi, 4),
            "single_match": round(single, 4),
            "attenuation_pct": round(100 * (multi - single) / multi, 3),
        })
    out = {
        "table": "I",
        "paper_attenuation_range_pct": [0.86, 5.39],
        "rows": rows,
        "trend_ok": all(
            rows[i]["attenuation_pct"] <= rows[i + 1]["attenuation_pct"] + 0.6
            for i in range(len(rows) - 1)
        ),
    }
    save_json("table1", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
