"""Decompression throughput: serial `decode_frame` vs `LZ4DecodeEngine`,
and seekable `read_range` vs full-decode-then-slice.

Compares, on a multi-block corpus frame (round-trip verified):

  * serial chunked  — `decode_frame_serial` (the pre-PR-2 `decode_frame`:
    one Python loop over blocks, chunked `decode_block` per block);
  * serial bytewise — `decode_frame_serial(bytewise=True)`, the
    byte-at-a-time oracle (lower bound reference);
  * engine inline   — `LZ4DecodeEngine()` (fused chunked per-block decode,
    one worker: the default `decode_frame` path);
  * engine inline planned — same, forced onto the two-phase plan/execute
    per-block decoder (`two_phase=True`);
  * engine thread   — workers in {2, 4}, ThreadPoolExecutor;
  * engine process  — workers in {2, 4}, fork pool (true multi-core);
  * engine device   — `executor="device"`: host planning feeds vmapped jit
    plan execution (pointer-doubling resolve), adaptive and worst-case
    static round counts.  The `device` JSON section also records
    `host_bytes` for the fetch-to-host drain and for the
    `decode_to_device` restore path (0 with verification deferred) —
    transfer symmetry with `BENCH_engine_batched.json`'s `host_transfer`.
    On this CPU container the "device" is the host, so the numbers are
    bookkeeping, not the accelerator end-state (see docs/tuning.md);
  * engine device specplan — `executor="device", plan_on_device=True`:
    the speculative in-graph planner (PR 9) replaces the host
    `plan_block_fast` walk, so plan+execute+CRC is one fused jit dispatch
    per micro-batch.  The `plan_stage` JSON section times the retired
    host O(n) stage (`plan_block_fast` over every compressed payload) so
    the ledger shows exactly what left the host, and asserts the
    restore-path `host_bytes` stays 0 *including planning*.

Configs are timed INTERLEAVED (one rep of each per round, min over rounds)
so CPU-frequency noise hits every config equally.  The random-access
section times N scattered 4 KB reads through `FrameReader.read_range`
(decodes only covering blocks, LRU off to keep it honest) against decoding
the whole frame per read and slicing.

JSON lands in experiments/benchmarks/decode_parallel.json and is mirrored
to BENCH_decode_parallel.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (
    FrameReader,
    LZ4DecodeEngine,
    LZ4Engine,
    decode_frame_serial,
)
from repro.core.lz4_types import MAX_BLOCK

if __package__ in (None, ""):        # `python benchmarks/decode_parallel.py`
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import dump_telemetry, save_json
else:
    from .common import dump_telemetry, save_json


def _corpus(n_blocks: int) -> bytes:
    from repro.core import corpus_blocks

    full = [b for b in corpus_blocks() if len(b) == MAX_BLOCK]
    reps = -(-n_blocks // len(full))
    return b"".join((full * reps)[:n_blocks])


def _process_available() -> bool:
    import multiprocessing as mp

    return "fork" in mp.get_all_start_methods()


def run(fast: bool = True, chaos_seed: int | None = None) -> dict:
    n_blocks = 16 if fast else 64
    rounds = 3 if fast else 5
    data = _corpus(n_blocks)
    frame = LZ4Engine(micro_batch=32).compress(data)

    configs: dict[str, object] = {
        "serial_chunked": lambda: decode_frame_serial(frame),
        "engine_inline": None,  # filled below with engine instances
    }
    engines = {
        "engine_inline": LZ4DecodeEngine(),
        "engine_inline_planned": LZ4DecodeEngine(two_phase=True),
    }
    for w in (2, 4):
        engines[f"engine_thread_w{w}"] = LZ4DecodeEngine(workers=w,
                                                         executor="thread")
    if _process_available():
        for w in (2, 4):
            engines[f"engine_process_w{w}"] = LZ4DecodeEngine(
                workers=w, executor="process")
    engines["engine_device"] = LZ4DecodeEngine(executor="device")
    engines["engine_device_static"] = LZ4DecodeEngine(
        executor="device", adaptive_rounds=False)
    engines["engine_device_specplan"] = LZ4DecodeEngine(
        executor="device", plan_on_device=True)
    for name, eng in engines.items():
        configs[name] = (lambda e: lambda: e.decode(frame))(eng)

    # Correctness gate before any timing.
    for name, fn in configs.items():
        assert fn() == data, f"{name} round-trip failed"

    best = {name: float("inf") for name in configs}
    for _ in range(rounds):  # interleaved: every config sees the same noise
        for name, fn in configs.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)

    # Bytewise oracle: far slower; one timed rep is plenty.
    t0 = time.perf_counter()
    assert decode_frame_serial(frame, bytewise=True) == data
    bytewise_s = time.perf_counter() - t0

    serial_s = best["serial_chunked"]
    out = {
        "corpus_blocks": n_blocks,
        "block_kb": 64,
        "frame_bytes": len(frame),
        "data_bytes": len(data),
        "serial_bytewise_ms": round(bytewise_s * 1000, 1),
        "configs": {},
    }
    for name, dt in best.items():
        out["configs"][name] = {
            "ms": round(dt * 1000, 1),
            "mbps": round(len(data) / dt / 1e6, 2),
            "speedup_vs_serial": round(serial_s / dt, 3),
        }
    parallel = [v["speedup_vs_serial"] for k, v in out["configs"].items()
                if k.startswith("engine_") and k != "engine_inline"]
    out["best_parallel_speedup"] = max(parallel) if parallel else None
    out["engine_inline_speedup"] = out["configs"]["engine_inline"][
        "speedup_vs_serial"]

    # -- device executor: transfer accounting + restore path ----------------
    dev = engines["engine_device"]
    assert dev.decode(frame) == data
    dev_stats = dev.stats
    t0 = time.perf_counter()
    arr = dev.decode_to_device(frame, verify=False)
    arr.block_until_ready()
    to_device_s = time.perf_counter() - t0
    assert dev.stats.host_bytes == 0, "decode_to_device(verify=False) fetched"
    out["device"] = {
        "ms": out["configs"]["engine_device"]["ms"],
        "mbps": out["configs"]["engine_device"]["mbps"],
        "speedup_vs_serial":
            out["configs"]["engine_device"]["speedup_vs_serial"],
        "static_rounds_ms": out["configs"]["engine_device_static"]["ms"],
        "dispatches": dev_stats.dispatches,
        "device_blocks": dev_stats.device_blocks,
        "fallback_blocks": dev_stats.fallback_blocks,
        "host_bytes": dev_stats.host_bytes,          # == decoded payload
        "to_device_ms": round(to_device_s * 1000, 1),
        "to_device_host_bytes": 0,                   # asserted above
    }

    # -- speculative in-graph planning: the retired host O(n) stage ---------
    # Time plan_block_fast (the serial token-stream walk the speculative
    # planner replaces) over every compressed payload, then put the fused
    # specplan engine's ledger next to it: same decode, zero host planning.
    from repro.core.decode_plan import plan_block_fast
    from repro.core.frame import frame_info

    info = frame_info(frame)
    payloads = [frame[b["offset"]: b["offset"] + b["csize"]]
                for b in info["blocks"] if not b["raw"]]
    host_plan_s = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for p in payloads:
            plan_block_fast(p)
        host_plan_s = min(host_plan_s, time.perf_counter() - t0)

    spec = engines["engine_device_specplan"]
    assert spec.decode(frame) == data
    spec_stats = spec.stats
    assert spec_stats.fallback_blocks == 0, "specplan fell back on corpus"
    t0 = time.perf_counter()
    arr = spec.decode_to_device(frame, verify=False)
    arr.block_until_ready()
    spec_to_device_s = time.perf_counter() - t0
    assert spec.stats.host_bytes == 0, \
        "specplan decode_to_device touched host bytes (planning leaked?)"
    out["plan_stage"] = {
        "compressed_blocks": len(payloads),
        "host_plan_ms": round(host_plan_s * 1000, 1),     # the retired stage
        "specplan_ms": out["configs"]["engine_device_specplan"]["ms"],
        "specplan_mbps": out["configs"]["engine_device_specplan"]["mbps"],
        "dispatches": spec_stats.dispatches,
        "device_blocks": spec_stats.device_blocks,
        "fallback_blocks": spec_stats.fallback_blocks,     # asserted 0
        "host_bytes": spec_stats.host_bytes,               # == decoded payload
        "to_device_ms": round(spec_to_device_s * 1000, 1),
        "to_device_host_bytes": 0,                         # asserted above
    }

    # -- random access: read_range vs full-decode-then-slice ----------------
    rng = np.random.default_rng(0)
    n_reads, read_len = 32, 4096
    offsets = [int(rng.integers(0, len(data) - read_len)) for _ in range(n_reads)]
    reader = FrameReader(frame, cache_blocks=0)
    for off in offsets[:4]:
        assert reader.read_range(off, read_len) == data[off: off + read_len]

    t0 = time.perf_counter()
    for off in offsets:
        reader.read_range(off, read_len)
    ranged_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for off in offsets[: max(2, n_reads // 8)]:  # full decode per read is slow
        decode_frame_serial(frame)[off: off + read_len]
    full_s = (time.perf_counter() - t0) / max(2, n_reads // 8) * n_reads
    out["random_access"] = {
        "reads": n_reads,
        "read_bytes": read_len,
        "read_range_ms_per_read": round(ranged_s / n_reads * 1000, 3),
        "full_decode_ms_per_read": round(full_s / n_reads * 1000, 3),
        "speedup": round(full_s / ranged_s, 1),
    }

    # -- optional chaos leg: salvage the same frame after seeded damage -----
    # One corrupt block, no parity (this corpus frame is v3): salvage must
    # recover every OTHER block and account the loss — never silently.
    if chaos_seed is not None:
        from repro.core.frame import frame_info as _fi
        from repro.resilience.inject import corrupt_frame_block
        from repro.resilience.salvage import salvage_frame

        n = _fi(frame)["block_count"]
        victim = chaos_seed % n
        bad = corrupt_frame_block(frame, victim, seed=chaos_seed, n=3)
        t0 = time.perf_counter()
        rep = salvage_frame(bad, engines["engine_inline"])
        salvage_s = time.perf_counter() - t0
        assert rep.lost == [victim], f"chaos: lost {rep.lost} != [{victim}]"
        assert len(rep.ok) == n - 1, "chaos: an undamaged block was lost"
        assert len(rep.data) == len(data)
        out["chaos"] = {
            "seed": chaos_seed,
            "damaged_block": victim,
            "recovered_blocks": len(rep.ok),
            "lost_blocks": len(rep.lost),
            "salvage_ms": round(salvage_s * 1000, 1),
        }

    for eng in engines.values():
        eng.close()
    save_json("decode_parallel", out)
    root = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_decode_parallel.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=1)
    # With REPRO_OBS=1: export the read-path trace/metrics bundle
    # (plan/execute/verify spans across every executor) for
    # tools/trace_report.py; no-op otherwise.
    dump_telemetry("decode_parallel")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="also run a seeded-corruption salvage leg "
                         "(repro.resilience.inject) and record its ledger")
    args = ap.parse_args()
    print(json.dumps(run(fast=not args.full, chaos_seed=args.chaos),
                     indent=1))
