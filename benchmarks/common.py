"""Shared helpers for the benchmark harness (one module per paper table).

Timing discipline (normalized across every benchmarks/*.py module):

  * `time.perf_counter` for ALL wall-clock intervals (monotonic,
    high-resolution; never `time.time`);
  * best-of-N over INTERLEAVED or repeated reps via `timed` / `timed_best`;
  * every JSON written through `save_json` carries a ``schema_version``
    plus ``wall_time_s`` / ``process_time_s`` (elapsed since benchmark
    start) so BENCH_*.json files are machine-diffable across PRs — a
    schema bump means the shape of the payload changed, not just numbers.

Telemetry: `dump_telemetry(name)` exports the `repro.obs` trace/metrics
bundle to experiments/telemetry/<name>/ when ``REPRO_OBS`` is on (the
artifact `tools/trace_report.py` consumes); it is a no-op otherwise.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import corpus_blocks, corpus_files, plan_size
from repro.core.lz4_types import Sequence

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "benchmarks")
TELEMETRY_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                             "telemetry")

# Bump when the shape of a benchmark JSON changes (not its numbers).
BENCH_SCHEMA_VERSION = 2

# Process-start-ish origin for the wall/process elapsed fields: importing
# benchmarks.common is the first thing every benchmark module does.
_T0_WALL = time.perf_counter()
_T0_PROC = time.process_time()

ENTRY_SWEEP = [64, 128, 256, 512, 1024, 2048, 4096, 8192]


def bits(entries: int) -> int:
    return int(entries).bit_length() - 1


def corpus_subset(fast: bool = True) -> list[bytes]:
    """Blocks used in ratio sweeps. fast=True uses a ~⅓ subset."""
    blocks = corpus_blocks()
    if fast:
        return blocks[::3]
    return blocks


def corpus_ratio(compress_fn, blocks: list[bytes]) -> float:
    """Paper's definition: avg original size / avg compressed size."""
    orig = sum(len(b) for b in blocks)
    comp = 0
    for b in blocks:
        comp += compress_fn(b)
    return orig / comp


def save_json(name: str, obj) -> str:
    """Write a benchmark JSON, stamping the machine-diffable header fields.

    Mutates ``obj`` in place (schema_version / wall_time_s / process_time_s)
    so callers that mirror the same dict elsewhere — the BENCH_*.json root
    copies — carry identical headers.
    """
    if isinstance(obj, dict):
        obj["schema_version"] = BENCH_SCHEMA_VERSION
        obj["wall_time_s"] = round(time.perf_counter() - _T0_WALL, 3)
        obj["process_time_s"] = round(time.process_time() - _T0_PROC, 3)
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # warmup / jit
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, min(ts)


def timed_best(fn, repeat: int) -> float:
    """Best-of-`repeat` wall time of `fn()` after one warmup call (the
    shared form of the per-module `_timed` helpers)."""
    fn()  # warmup / jit
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def dump_telemetry(name: str) -> dict | None:
    """Export the obs trace/metrics bundle for this benchmark run.

    Writes experiments/telemetry/<name>/{trace.json,events.jsonl,
    metrics.json,metrics.prom} when telemetry is enabled (``REPRO_OBS=1``);
    returns the path map, or None when telemetry is off.
    """
    from repro import obs

    if not obs.is_enabled():
        return None
    return obs.dump_artifacts(os.path.join(TELEMETRY_DIR, name))
