"""Shared helpers for the benchmark harness (one module per paper table)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import corpus_blocks, corpus_files, plan_size
from repro.core.lz4_types import Sequence

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "benchmarks")

ENTRY_SWEEP = [64, 128, 256, 512, 1024, 2048, 4096, 8192]


def bits(entries: int) -> int:
    return int(entries).bit_length() - 1


def corpus_subset(fast: bool = True) -> list[bytes]:
    """Blocks used in ratio sweeps. fast=True uses a ~⅓ subset."""
    blocks = corpus_blocks()
    if fast:
        return blocks[::3]
    return blocks


def corpus_ratio(compress_fn, blocks: list[bytes]) -> float:
    """Paper's definition: avg original size / avg compressed size."""
    orig = sum(len(b) for b in blocks)
    comp = 0
    for b in blocks:
        comp += compress_fn(b)
    return orig / comp


def save_json(name: str, obj) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # warmup / jit
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, min(ts)
