"""Paper Table III: combined scheme (single-match + cap 36) vs GitHub software
LZ4, over hash-table sizes.  The combined scheme here is the JAX engine
itself (vectorized, jit), proving the production path achieves the paper's
ratios; its records are golden-model-exact (tests/test_lz4_jax.py).

Claim reproduced: combined attenuation ~5-12%, growing with table size.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compress_greedy, plan_size
from repro.core.jax_compressor import compress_block_records, pad_block

from .common import ENTRY_SWEEP, bits, corpus_ratio, corpus_subset, save_json


def _jax_size(block: bytes, hb: int) -> int:
    buf, n = pad_block(block)
    rec = compress_block_records(
        jnp.asarray(buf), jnp.int32(n), hash_bits=hb, max_match=36
    )
    return int(rec.size)


def run(fast: bool = True) -> dict:
    blocks = corpus_subset(fast)
    rows = []
    for entries in ENTRY_SWEEP:
        hb = bits(entries)
        github = corpus_ratio(lambda b: plan_size(compress_greedy(b, hash_bits=hb)), blocks)
        combined = corpus_ratio(lambda b: _jax_size(b, hb), blocks)
        rows.append({
            "entries": entries,
            "github": round(github, 4),
            "combined": round(combined, 4),
            "attenuation_pct": round(100 * (github - combined) / github, 3),
        })
    out = {
        "table": "III",
        "paper_attenuation_range_pct": [4.93, 11.68],
        "rows": rows,
        "grows_with_entries": rows[-1]["attenuation_pct"] > rows[0]["attenuation_pct"],
    }
    save_json("table3", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
