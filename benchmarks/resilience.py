"""Resilience benchmark: frame-v6 parity write overhead + salvage throughput.

Measures, on a multi-block corpus frame (round-trip verified):

  * parity write overhead — `LZ4Engine(parity_group=G)` for G in {2, 4, 8}
    vs the parity-off baseline: frame size overhead (one XOR parity block
    per G-block group) and compress-time overhead.  Asserts the
    parity-off frame is BYTE-IDENTICAL to the plain engine's (the parity
    feature costs nothing when off);
  * salvage throughput — `salvage_frame` over a seeded-corrupted v6 frame
    (one damaged block per parity group: worst case that still
    reconstructs fully) across the serial / thread / process / device
    executors, MB/s of recovered output.  Every pass must come back
    ``complete`` with ``data`` byte-identical to the original — the
    benchmark doubles as an acceptance check;
  * strict-decode comparison — the undamaged strict decode time next to
    the salvage pass, so the overhead of the recovery path is visible.

``--chaos SEED`` re-seeds every injected corruption (block choice + bit
flips) from one integer — the CI chaos legs sweep a fixed seed matrix and
pin the salvage/reconstruction accounting.  ``--full`` grows the corpus.

JSON lands in experiments/benchmarks/resilience.json and is mirrored to
BENCH_resilience.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import LZ4DecodeEngine, LZ4Engine, frame_info
from repro.core.lz4_types import MAX_BLOCK
from repro.resilience.inject import corrupt_frame_block
from repro.resilience.salvage import salvage_frame

if __package__ in (None, ""):        # `python benchmarks/resilience.py`
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import dump_telemetry, save_json
else:
    from .common import dump_telemetry, save_json

PARITY_GROUPS = [2, 4, 8]


def _corpus(n_blocks: int) -> bytes:
    from repro.core import corpus_blocks

    full = [b for b in corpus_blocks() if len(b) == MAX_BLOCK]
    reps = -(-n_blocks // len(full))
    return b"".join((full * reps)[:n_blocks])


def _process_available() -> bool:
    import multiprocessing as mp

    return "fork" in mp.get_all_start_methods()


def _timed_best(fn, rounds: int) -> float:
    fn()  # warmup / jit
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = True, chaos_seed: int = 0) -> dict:
    n_blocks = 16 if fast else 64
    rounds = 3 if fast else 5
    data = _corpus(n_blocks)

    # -- parity write overhead ---------------------------------------------
    base_engine = LZ4Engine(micro_batch=32)
    base_frame = base_engine.compress(data)
    # Parity off is free: byte-identical to the plain engine's frame.
    assert LZ4Engine(micro_batch=32, parity_group=None).compress(data) \
        == base_frame, "parity_group=None changed the frame bytes"
    base_s = _timed_best(lambda: base_engine.compress(data), rounds)

    out = {
        "corpus_blocks": n_blocks,
        "block_kb": 64,
        "data_bytes": len(data),
        "chaos_seed": chaos_seed,
        "parity_off": {
            "frame_bytes": len(base_frame),
            "compress_ms": round(base_s * 1000, 1),
            "byte_identical_to_plain_engine": True,  # asserted above
        },
        "parity": {},
        "salvage": {},
    }
    for g in PARITY_GROUPS:
        eng = LZ4Engine(micro_batch=32, parity_group=g)
        frame = eng.compress(data)
        dt = _timed_best(lambda e=eng: e.compress(data), rounds)
        info = frame_info(frame)
        out["parity"][f"group_{g}"] = {
            "frame_bytes": len(frame),
            "size_overhead_pct": round(
                (len(frame) - len(base_frame)) / len(base_frame) * 100, 2),
            "parity_blocks": len(info["parity"]),
            "compress_ms": round(dt * 1000, 1),
            "time_overhead_pct": round((dt - base_s) / base_s * 100, 1),
        }

    # -- salvage throughput across executors --------------------------------
    # Worst recoverable case: ONE damaged block in EVERY parity group, so
    # the pass decodes all survivors and reconstructs a block per group.
    g = 4
    v6 = LZ4Engine(micro_batch=32, parity_group=g).compress(data)
    info = frame_info(v6)
    n = info["block_count"]
    bad = v6
    victims = []
    for grp in range(-(-n // g)):
        victim = grp * g + (chaos_seed + grp) % min(g, n - grp * g)
        victims.append(victim)
        bad = corrupt_frame_block(bad, victim, seed=chaos_seed + grp, n=3)

    engines = {"serial": LZ4DecodeEngine(executor="serial"),
               "thread_w4": LZ4DecodeEngine(executor="thread", workers=4)}
    if _process_available():
        engines["process_w4"] = LZ4DecodeEngine(executor="process", workers=4)
    engines["device"] = LZ4DecodeEngine(executor="device")

    strict_s = _timed_best(lambda: engines["serial"].decode(v6), rounds)
    out["strict_decode_ms"] = round(strict_s * 1000, 1)
    for name, eng in engines.items():
        rep = salvage_frame(bad, eng)
        # Acceptance, not just timing: full recovery, byte-identical.
        assert rep.complete, f"{name}: salvage lost blocks {rep.lost}"
        assert sorted(rep.reconstructed) == sorted(victims), \
            f"{name}: reconstructed {rep.reconstructed} != {victims}"
        assert rep.data == data, f"{name}: salvage output differs"
        assert rep.content_crc_ok, f"{name}: content CRC did not re-verify"
        dt = _timed_best(lambda e=eng: salvage_frame(bad, e), rounds)
        out["salvage"][name] = {
            "ms": round(dt * 1000, 1),
            "mbps": round(len(data) / dt / 1e6, 2),
            "vs_strict_decode_x": round(dt / strict_s, 2),
            "reconstructed_blocks": len(rep.reconstructed),
        }

    # -- no-parity loss accounting (the chaos ledger CI pins) ---------------
    bad_v3 = corrupt_frame_block(base_frame, chaos_seed % n, n=3,
                                 seed=chaos_seed)
    rep = salvage_frame(bad_v3, engines["serial"])
    assert rep.lost == [chaos_seed % n] and not rep.reconstructed
    assert len(rep.ok) == n - 1, "salvage missed an undamaged block"
    out["no_parity_salvage"] = {
        "lost_blocks": len(rep.lost),
        "recovered_blocks": len(rep.ok),
        "hole_bytes": sum(e - s for s, e in rep.holes),
    }

    for eng in engines.values():
        eng.close()
    save_json("resilience", out)
    root = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_resilience.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=1)
    dump_telemetry("resilience")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--chaos", type=int, default=0, metavar="SEED",
                    help="seed for every injected corruption (CI sweeps a "
                         "fixed matrix of these)")
    args = ap.parse_args()
    print(json.dumps(run(fast=not args.full, chaos_seed=args.chaos),
                     indent=1))
