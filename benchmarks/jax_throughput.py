"""Measured throughput of the JAX engine (beyond-paper): CPU wall-clock here,
plus the TPU v5e roofline projection derived from the engine's per-byte
data movement (the engine is memory-bound; see EXPERIMENTS.md §Roofline).

Variants measured: scan_impl sequential vs associative (the beyond-paper
parallel selection) at the kernel level, plus the end-to-end batched
LZ4Engine pipeline (micro-batched dispatch + vectorized emission + framing).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import LZ4Engine
from repro.core.jax_compressor import compress_block_records, pad_block
from repro.core.lz4_types import MAX_BLOCK

from .common import save_json, timed

# Per input byte, the engine moves (roofline accounting, bf16/int32 in VMEM/HBM):
#   hash+word build ~ 8 B, sort (log passes over 4B keys) ~ 16 B amortized,
#   candidate/valid masks ~ 12 B, bounded extend gather 2*32 B, scan tables ~ 5 B
_BYTES_PER_BYTE = 8 + 16 + 12 + 64 + 5
_V5E_HBM = 819e9


def run(fast: bool = True) -> dict:
    rng = np.random.default_rng(0)
    data = rng.integers(0, 48, MAX_BLOCK, dtype=np.uint8).tobytes()
    buf, n = pad_block(data)
    buf_j = jnp.asarray(buf)
    n_j = jnp.int32(n)

    out = {"block_kb": 64}
    for impl in ("sequential", "associative"):
        _, dt = timed(
            lambda: compress_block_records(buf_j, n_j, scan_impl=impl).size.block_until_ready(),
            repeat=3,
        )
        out[f"cpu_mbps_{impl}"] = round(MAX_BLOCK / dt / 1e6, 2)
    for cand in ("sortkey", "scatter", "fused"):
        _, dt = timed(
            lambda: compress_block_records(
                buf_j, n_j, scan_impl="associative", candidate_impl=cand
            ).size.block_until_ready(),
            repeat=3,
        )
        out[f"cpu_mbps_cand_{cand}"] = round(MAX_BLOCK / dt / 1e6, 2)

    # End-to-end batched pipeline: micro-batched dispatch, vectorized
    # emission, frame output (and the round trip is free to check here).
    nb = 4 if fast else 16
    batch_data = data * nb
    eng = LZ4Engine(micro_batch=nb, scan_impl="associative")
    _, dt = timed(lambda: eng.compress(batch_data), repeat=3)
    out["cpu_mbps_batch"] = round(len(batch_data) / dt / 1e6, 2)
    out["engine_dispatches"] = eng.stats.dispatches
    out["tpu_v5e_roofline_gbps_per_chip"] = round(8 * _V5E_HBM / _BYTES_PER_BYTE / 1e9, 1)
    out["paper_fpga_gbps"] = 16.10
    save_json("jax_throughput", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
