"""Paper Table II: compression ratio of the length-capped extended-match stage
(caps 12/20/36/68 vs unbounded) over hash-table sizes.

Claim reproduced: the ratio loss SHRINKS as the cap grows (the paper picks 36
as the ratio/hardware-cost sweet spot).
"""
from __future__ import annotations

from repro.core import compress_greedy, plan_size

from .common import ENTRY_SWEEP, bits, corpus_ratio, corpus_subset, save_json

CAPS = [None, 12, 20, 36, 68]


def run(fast: bool = True) -> dict:
    blocks = corpus_subset(fast)
    rows = []
    for entries in ENTRY_SWEEP:
        hb = bits(entries)
        row = {"entries": entries}
        for cap in CAPS:
            r = corpus_ratio(
                lambda b: plan_size(compress_greedy(b, hash_bits=hb, max_match=cap)),
                blocks,
            )
            row["no_limit" if cap is None else f"limit_{cap}"] = round(r, 4)
        rows.append(row)
    # attenuation at cap=36 (paper: 4.46%..8.23%)
    att36 = [
        100 * (r["no_limit"] - r["limit_36"]) / r["no_limit"] for r in rows
    ]
    out = {
        "table": "II",
        "paper_attenuation_36_range_pct": [4.46, 8.23],
        "rows": rows,
        "attenuation_36_pct": [round(a, 3) for a in att36],
        "monotone_in_cap": all(
            r["limit_12"] <= r["limit_20"] <= r["limit_36"] <= r["limit_68"] <= r["no_limit"]
            for r in rows
        ),
    }
    save_json("table2", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
