"""Batched LZ4Engine throughput vs the serial per-block baseline.

Measures blocks/s of `LZ4Engine.compress` (one dispatch per micro-batch,
vectorized emission, frame output) over micro-batch sizes {1, 8, 32, 128}
against the deprecated serial path (`compress_bytes`: one dispatch per 64 KB
block + Python byte-loop emission) on the same corpus and kernel config.

JSON lands in experiments/benchmarks/engine_batched.json and is mirrored to
BENCH_engine_batched.json at the repo root so the perf trajectory is easy to
diff across PRs.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import LZ4Engine, decode_frame
from repro.core.lz4_types import MAX_BLOCK

from .common import save_json

BATCH_SIZES = (1, 8, 32, 128)


def _corpus(n_blocks: int) -> bytes:
    from repro.core import corpus_blocks

    full = [b for b in corpus_blocks() if len(b) == MAX_BLOCK]
    reps = -(-n_blocks // len(full))
    return b"".join((full * reps)[:n_blocks])


def _timed(fn, repeat: int):
    fn()  # warmup / jit
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = True) -> dict:
    n_blocks = 32 if fast else 128
    sizes = [b for b in BATCH_SIZES if b <= n_blocks]
    repeat = 1 if fast else 2
    data = _corpus(n_blocks)

    out = {"corpus_blocks": n_blocks, "block_kb": 64, "batch": {}}

    # Serial baseline: the pre-refactor compress_bytes path — one jit
    # dispatch per 64 KB block, then Python byte loops for emission.
    # (compress_bytes itself now delegates to the engine, so the legacy
    # shape is reconstructed here from its original building blocks.)
    import jax.numpy as jnp

    from repro.core.encoder import encode_block
    from repro.core.jax_compressor import (
        compress_block_records,
        pad_block,
        records_to_plan,
    )

    def serial():
        blocks = []
        for i in range(0, len(data), MAX_BLOCK):
            chunk = data[i: i + MAX_BLOCK]
            buf, n = pad_block(chunk)
            rec = compress_block_records(jnp.asarray(buf), jnp.int32(n))
            blocks.append(encode_block(chunk, records_to_plan(rec, n)))
        return blocks

    dt = _timed(serial, repeat)
    out["serial_blocks_per_s"] = round(n_blocks / dt, 2)
    out["serial_mbps"] = round(len(data) / dt / 1e6, 2)

    for b in sizes:
        eng = LZ4Engine(micro_batch=b)
        frame = eng.compress(data)
        assert decode_frame(frame) == data, "engine round-trip failed"
        dt = _timed(lambda: eng.compress(data), repeat)
        out["batch"][str(b)] = {
            "blocks_per_s": round(n_blocks / dt, 2),
            "mbps": round(len(data) / dt / 1e6, 2),
            "dispatches": eng.stats.dispatches,
        }
    best = max(v["blocks_per_s"] for v in out["batch"].values())
    out["speedup_best_vs_serial"] = round(best / out["serial_blocks_per_s"], 3)
    save_json("engine_batched", out)
    root = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine_batched.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(json.dumps(run(fast=not args.full), indent=1))
