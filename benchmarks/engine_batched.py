"""Batched LZ4Engine throughput vs the serial per-block baseline.

Measures blocks/s of `LZ4Engine.compress` over micro-batch sizes
{1, 8, 32, 128} for BOTH emission paths — ``device_emit=True`` (byte
emission inside the jit graph, one padded uint8 buffer + size scalar
crossing the host boundary per block) and ``device_emit=False`` (per-window
match records fetched to host, vectorized NumPy emission) — against the
pre-refactor serial path (one dispatch per 64 KB block + Python byte-loop
emission) on the same corpus and kernel config.

Also records, per path:
  * host-transfer bytes (`EngineStats.host_bytes`): the device-emit path
    must move fewer bytes across the host boundary than the records path —
    this is the acceptance metric for device-side emission;
  * emit-stage throughput: the host emitter timed alone on pre-fetched
    records, vs the device path's fused emit (reported as the marginal
    pipeline cost, since in-graph emission cannot be timed separately);
  * the `candidate_impl` sweep (sort / sortkey / scatter / fused / auto) at
    the default micro-batch: all five produce byte-identical frames, and
    the fastest non-sort impl beating "sort" is the acceptance metric for
    retiring the 64K-element candidate sort (`best_non_sort_vs_sort_x`).

JSON lands in experiments/benchmarks/engine_batched.json and is mirrored to
BENCH_engine_batched.json at the repo root so the perf trajectory is easy to
diff across PRs.  Methodology notes + measured tables: EXPERIMENTS.md;
parameter guidance distilled from these numbers: docs/tuning.md.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import LZ4Engine, decode_frame
from repro.core.lz4_types import MAX_BLOCK

if __package__ in (None, ""):        # `python benchmarks/engine_batched.py`
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import dump_telemetry, save_json, timed_best
else:
    from .common import dump_telemetry, save_json, timed_best

BATCH_SIZES = (1, 8, 32, 128)


def _corpus(n_blocks: int) -> bytes:
    from repro.core import corpus_blocks

    full = [b for b in corpus_blocks() if len(b) == MAX_BLOCK]
    reps = -(-n_blocks // len(full))
    return b"".join((full * reps)[:n_blocks])


_timed = timed_best


def run(fast: bool = True) -> dict:
    n_blocks = 32 if fast else 128
    sizes = [b for b in BATCH_SIZES if b <= n_blocks]
    repeat = 1 if fast else 2
    data = _corpus(n_blocks)

    out = {"corpus_blocks": n_blocks, "block_kb": 64}

    # Serial baseline: the pre-refactor compress_bytes path — one jit
    # dispatch per 64 KB block, then Python byte loops for emission.
    # (compress_bytes itself now delegates to the engine, so the legacy
    # shape is reconstructed here from its original building blocks.)
    import jax.numpy as jnp

    from repro.core.encoder import encode_block
    from repro.core.jax_compressor import (
        compress_block_records,
        pad_block,
        records_to_plan,
    )

    def serial():
        blocks = []
        for i in range(0, len(data), MAX_BLOCK):
            chunk = data[i: i + MAX_BLOCK]
            buf, n = pad_block(chunk)
            # candidate_impl pinned to the historical "sort" — this column
            # reconstructs the PRE-refactor path; letting it float with the
            # "auto" default would silently redefine the baseline.
            rec = compress_block_records(jnp.asarray(buf), jnp.int32(n),
                                         candidate_impl="sort")
            blocks.append(encode_block(chunk, records_to_plan(rec, n)))
        return blocks

    dt = _timed(serial, repeat)
    out["serial_blocks_per_s"] = round(n_blocks / dt, 2)
    out["serial_mbps"] = round(len(data) / dt / 1e6, 2)

    # Both engine emission paths over the micro-batch sweep.  "batch" and
    # "device_emit" keep their historical meaning — records + host emit vs
    # in-graph emit, BOTH pinned to candidate_impl="sort" — so the columns
    # stay diffable against older BENCH_engine_batched.json baselines; the
    # "candidate_impl" section below is where impl choice (incl. the
    # "auto" default) is measured.
    ref_frame = None
    for key, device_emit in (("batch", False), ("device_emit", True)):
        out[key] = {}
        for b in sizes:
            eng = LZ4Engine(micro_batch=b, device_emit=device_emit,
                            candidate_impl="sort")
            frame = eng.compress(data)
            assert decode_frame(frame) == data, "engine round-trip failed"
            if ref_frame is None:
                ref_frame = frame
            assert frame == ref_frame, "emission paths disagree on frame bytes"
            dt = _timed(lambda: eng.compress(data), repeat)
            out[key][str(b)] = {
                "blocks_per_s": round(n_blocks / dt, 2),
                "mbps": round(len(data) / dt / 1e6, 2),
                "dispatches": eng.stats.dispatches,
                "host_bytes": eng.stats.host_bytes,
            }

    # Host-transfer accounting (acceptance metric for device-side emission):
    # bytes fetched device -> host for one full-corpus compress at the
    # default micro-batch.  The records path moves four (W,) arrays per
    # block; device emit with the default two-step drain moves the size
    # vector plus exactly `size` bytes per block (and nothing for
    # raw-passthrough blocks); drain="full" is the pre-two-step behaviour
    # (whole padded buffer per block), kept measured for the delta.
    mb = str(min(32, max(sizes)))
    records_bytes = out["batch"][mb]["host_bytes"]
    device_bytes = out["device_emit"][mb]["host_bytes"]
    full_eng = LZ4Engine(micro_batch=int(mb), drain="full",
                         candidate_impl="sort")
    assert full_eng.compress(data) == ref_frame
    full_bytes = full_eng.stats.host_bytes
    out["host_transfer"] = {
        "micro_batch": int(mb),
        "records_path_bytes": records_bytes,
        "device_emit_bytes": device_bytes,
        "device_emit_full_drain_bytes": full_bytes,
        "reduction_x": round(records_bytes / device_bytes, 3),
        "sliced_vs_full_drain_x": round(full_bytes / device_bytes, 3),
    }

    # Candidate-resolution sweep (PR 5): the four bit-identical impls plus
    # the "auto" default, on the default micro-batch and emission path.
    # "sort" is the pre-PR-5 default (full 64K-element argsort per block);
    # "fused" runs the single-pass datapath — here via its jnp twin, since
    # interpret-mode Pallas is a correctness tool, not a CPU fast path.
    # Configs are timed INTERLEAVED (one rep each per round, min over
    # rounds) so CPU-frequency noise hits every impl equally — "auto" must
    # read like the impl it resolved to, not like whichever config drew
    # the thermal short straw.
    out["candidate_impl"] = {"micro_batch": int(mb)}
    sweep = ("sort", "sortkey", "scatter", "fused", "auto")
    sweep_engines = {}
    for impl in sweep:
        eng = LZ4Engine(micro_batch=int(mb), candidate_impl=impl)
        frame = eng.compress(data)  # warmup/jit + frame-identity check
        assert frame == ref_frame, f"candidate_impl={impl} frame differs"
        sweep_engines[impl] = eng
    sweep_best = {impl: float("inf") for impl in sweep}
    for _ in range(repeat + 2):
        for impl in sweep:
            t0 = time.perf_counter()
            sweep_engines[impl].compress(data)
            sweep_best[impl] = min(sweep_best[impl],
                                   time.perf_counter() - t0)
    for impl in sweep:
        out["candidate_impl"][impl] = {
            "blocks_per_s": round(n_blocks / sweep_best[impl], 2),
            "mbps": round(len(data) / sweep_best[impl] / 1e6, 2),
            "resolved": sweep_engines[impl].stats.candidate_impl,
        }
    best_bps, best_impl = max(
        (out["candidate_impl"][i]["blocks_per_s"], i)
        for i in ("sortkey", "scatter", "fused")
    )
    out["candidate_impl"]["best_non_sort"] = best_impl
    out["candidate_impl"]["best_non_sort_vs_sort_x"] = round(
        best_bps / out["candidate_impl"]["sort"]["blocks_per_s"], 3)

    # Emit-stage throughput.  The host emitter can be timed in isolation
    # (records pre-fetched); the device emitter is fused into the dispatch,
    # so its cost shows up as the pipeline delta between the two paths.
    import numpy as np

    recs = []
    for i in range(0, len(data), MAX_BLOCK):
        chunk = data[i: i + MAX_BLOCK]
        buf, n = pad_block(chunk)
        rec = compress_block_records(jnp.asarray(buf), jnp.int32(n),
                                     candidate_impl="sort")
        recs.append((chunk, np.asarray(rec.emit), np.asarray(rec.pos),
                     np.asarray(rec.length), np.asarray(rec.offset), n))

    from repro.core.emitter import emit_block

    def host_emit_all():
        return [emit_block(c, e, p, l, o, n) for c, e, p, l, o, n in recs]

    dt = _timed(host_emit_all, repeat)
    out["emit_throughput"] = {
        "host_emit_blocks_per_s": round(n_blocks / dt, 2),
        "host_emit_mbps": round(len(data) / dt / 1e6, 2),
        "device_pipeline_mbps": out["device_emit"][mb]["mbps"],
        "records_pipeline_mbps": out["batch"][mb]["mbps"],
    }

    best = max(v["blocks_per_s"]
               for key in ("batch", "device_emit") for v in out[key].values())
    out["speedup_best_vs_serial"] = round(best / out["serial_blocks_per_s"], 3)
    save_json("engine_batched", out)
    root = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine_batched.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=1)
    # With REPRO_OBS=1: export the write-path trace/metrics bundle
    # (dispatch/wait/drain spans, engine.* counters, block-ratio histogram)
    # for tools/trace_report.py; no-op otherwise.
    dump_telemetry("engine_batched")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(json.dumps(run(fast=not args.full), indent=1))
