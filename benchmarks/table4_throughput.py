"""Paper Table IV: throughput.  FPGA clocks cannot be measured here; the
cycle model (repro.core.cycle_model) reproduces the paper's throughput
arithmetic on real corpus data:

  * ours: 1 window/cycle deterministic -> PWS * f = 16.10 Gb/s @ 251.57 MHz,
    INDEPENDENT of data content (the whole point of S1+S2);
  * multi-match baseline: loses cycles to extra matches + unbounded extension
    feedback trips -> reproduces the ~30-40% parallelism loss the paper
    attributes to [10]/[11] (10->6.08, 6.4->4.5 Gb/s).
"""
from __future__ import annotations

import numpy as np

from repro.core import compress_windowed_multi
from repro.core.cycle_model import (
    FREQ_BENES_MHZ,
    FREQ_OURS_MHZ,
    baseline_throughput,
    ours_throughput,
    peak_gbps,
)

from .common import bits, corpus_subset, save_json, timed


def _engine_measured_mbps(blocks: list[bytes]) -> float:
    """Wall-clock MB/s of the batched LZ4Engine on the same corpus subset."""
    from repro.core import LZ4Engine

    data = b"".join(blocks)
    eng = LZ4Engine(micro_batch=min(32, max(len(blocks), 1)))
    _, dt = timed(lambda: eng.compress(data), repeat=1)
    return round(len(data) / dt / 1e6, 2)


def run(fast: bool = True) -> dict:
    blocks = corpus_subset(fast)
    ours_bpc = []
    base_bpc = []
    for b in blocks:
        ours_bpc.append(ours_throughput(len(b)).bytes_per_cycle)
        res = compress_windowed_multi(b, hash_bits=bits(256))
        base_bpc.append(baseline_throughput(res, len(b)).bytes_per_cycle)
    ours_eff = float(np.mean(ours_bpc))
    base_eff = float(np.mean(base_bpc))
    out = {
        "table": "IV",
        "pws": 8,
        "ours": {
            "bytes_per_cycle": round(ours_eff, 3),
            "freq_mhz": FREQ_OURS_MHZ,
            "gbps": round(ours_eff * FREQ_OURS_MHZ * 8 / 1000, 2),
            "deterministic": True,
        },
        "paper_ours_gbps": 16.10,
        "baseline_multi_match": {
            "bytes_per_cycle": round(base_eff, 3),
            "freq_mhz": FREQ_BENES_MHZ,
            "gbps": round(base_eff * FREQ_BENES_MHZ * 8 / 1000, 2),
            "parallelism_loss_pct": round(100 * (1 - base_eff / 8.0), 1),
        },
        "paper_benes_gbps": 6.08,
        "engine_measured_cpu_mbps": _engine_measured_mbps(blocks),
        "peak_gbps_at_ours_freq": round(peak_gbps(), 2),
        "speedup_vs_baseline": round(
            (ours_eff * FREQ_OURS_MHZ) / (base_eff * FREQ_BENES_MHZ), 3
        ),
        "paper_speedup": 2.648,
    }
    save_json("table4", out)
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
