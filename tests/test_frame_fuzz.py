"""Malformed-frame fuzz corpus: corruption is NEVER silent.

Deterministic byte-flip and truncation sweeps over engine-produced (v2,
checksummed) frames, asserting that every corruption either raises
`FrameFormatError` (a subclass of `LZ4FormatError`) from both the parallel
decode engine and the serial oracle, or — the one legitimate escape —
decodes to exactly the original bytes (a flipped offset can land on an
identical copy of the match in periodic data, producing a different valid
encoding of the SAME content).  Never a crash, a hang, or a successful
decode of different bytes.  This is only possible because version-2 frames
carry a per-block CRC32 of the uncompressed content: a flipped literal byte
still parses as a valid token stream, so without the checksum it would
decode "successfully" to wrong data.

Plan-vs-bytewise oracle equality on random blocks lives in
test_decode_engine.py; here we additionally cross-check the two decode
paths agree on WHICH frames are malformed.
"""
import numpy as np
import pytest

from repro.core import (
    FrameFormatError,
    LZ4DecodeEngine,
    LZ4Engine,
    decode_frame,
    decode_frame_serial,
)
from repro.core.lz4_types import MAX_BLOCK

# Two-phase (vectorized-planner) decode path, exercised alongside the fused
# default and the serial oracle on every mutant.
_PLANNED = LZ4DecodeEngine(two_phase=True)


def _rng():
    return np.random.default_rng(20260731)


@pytest.fixture(scope="module")
def frames():
    rng = _rng()
    eng = LZ4Engine(micro_batch=4)
    corpora = {
        "empty": b"",
        "text": b"fuzz me gently, " * 900,                      # 1 block
        "multi": b"the quick brown fox " * 9000,                # 3 blocks
        "zeros": b"\x00" * (MAX_BLOCK + 5),                     # RLE-ish
        "raw": rng.integers(0, 256, 3000, np.uint8).tobytes(),  # passthrough
        "mix": (b"pattern! " * 8000
                + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()),
    }
    out = {}
    for name, data in corpora.items():
        frame = eng.compress(data)
        assert decode_frame(frame) == data
        out[name] = (data, frame)
    return out


def _assert_rejected(mutant: bytes, where: str, original: bytes | None = None):
    """Corrupt input must raise FrameFormatError — or, when `original` is
    given, be a coincidentally-still-valid encoding of the SAME bytes (a
    flipped offset can land on an identical copy of the match in periodic
    data; the checksum rightly accepts it).  What must never happen: any
    other exception type, or a successful decode of different bytes."""
    for label, fn in (("engine", decode_frame), ("serial", decode_frame_serial),
                      ("planned", _PLANNED.decode)):
        try:
            out = fn(mutant)
        except FrameFormatError:
            continue
        except Exception as e:  # crash class: wrong exception type
            pytest.fail(f"{where} [{label}]: raised {type(e).__name__}: {e}")
        else:
            if original is None or out != original:
                pytest.fail(f"{where} [{label}]: decoded corrupt frame silently")


def _flip_positions(n: int) -> list[int]:
    """Every byte for small frames; header/table + strided payload for big."""
    if n <= 600:
        return list(range(n))
    head = list(range(min(64, n)))                      # header + table region
    body = list(range(64, n, max(1, (n - 64) // 100)))  # ~100 payload probes
    return head + body + [n - 1]


@pytest.mark.parametrize("name", ["empty", "text", "multi", "zeros", "raw", "mix"])
def test_byte_flips_always_detected(frames, name):
    data, frame = frames[name]
    for pos in _flip_positions(len(frame)):
        for mask in (0x01, 0x80, 0xFF):
            mutant = bytearray(frame)
            mutant[pos] ^= mask
            _assert_rejected(bytes(mutant), f"{name}: flip {pos}^{mask:#x}",
                             original=data)


@pytest.mark.parametrize("name", ["empty", "text", "multi", "zeros", "raw", "mix"])
def test_truncations_always_detected(frames, name):
    _, frame = frames[name]
    n = len(frame)
    cuts = set(range(n)) if n <= 400 else (
        set(range(0, 60)) | set(range(60, n, max(1, n // 200))) | {n - 1}
    )
    for cut in sorted(cuts):
        _assert_rejected(frame[:cut], f"{name}: truncate to {cut}")


@pytest.mark.parametrize("name", ["empty", "text", "raw"])
def test_extension_always_detected(frames, name):
    _, frame = frames[name]
    for tail in (b"\x00", b"\xff" * 7, frame[:16]):
        _assert_rejected(frame + tail, f"{name}: extend by {len(tail)}")


def test_block_swap_detected(frames):
    # Swapping two equally-sized payload regions keeps every length field
    # consistent — only the per-block checksum can notice.
    data, frame = frames["multi"]
    from repro.core import frame_info

    info = frame_info(frame)
    b0, b1 = info["blocks"][0], info["blocks"][1]
    if b0["csize"] == b1["csize"]:  # depends on corpus; guard, don't skip silently
        mutant = bytearray(frame)
        p0 = mutant[b0["offset"]: b0["offset"] + b0["csize"]]
        p1 = mutant[b1["offset"]: b1["offset"] + b1["csize"]]
        mutant[b0["offset"]: b0["offset"] + b0["csize"]] = p1
        mutant[b1["offset"]: b1["offset"] + b1["csize"]] = p0
        if bytes(p0) != bytes(p1):
            _assert_rejected(bytes(mutant), "multi: payload swap")
    # Swapping the crc fields of two different blocks must also trip.
    mutant = bytearray(frame)
    table = 9 + 8  # v3 header: 9-byte base + 8-byte content size
    e0 = table + 0 * 12
    e1 = table + 1 * 12
    if mutant[e0 + 8: e0 + 12] != mutant[e1 + 8: e1 + 12]:
        mutant[e0 + 8: e0 + 12], mutant[e1 + 8: e1 + 12] = (
            mutant[e1 + 8: e1 + 12], mutant[e0 + 8: e0 + 12],
        )
        _assert_rejected(bytes(mutant), "multi: crc swap")


def test_corruption_never_hangs_or_overallocates(frames):
    # Flips in length-extension bytes can claim runs far past the block's
    # usize; the pre-copy cap must bound work and memory.  We just assert
    # the decode terminates quickly with the right error class on a frame
    # whose every payload byte is hostile.
    data, frame = frames["zeros"]
    rng = _rng()
    for _ in range(200):
        mutant = bytearray(frame)
        pos = int(rng.integers(9, len(frame)))
        mutant[pos] = int(rng.integers(0, 256))
        if bytes(mutant) == frame:
            continue
        _assert_rejected(bytes(mutant), f"zeros: rewrite {pos}", original=data)


# ---------------------------------------------------------------------------
# Frame v4 (sharded container): the shard table is a validation surface too.
# ---------------------------------------------------------------------------

# v4 header: 9-byte base + 8-byte content size + 4-byte shard count.
_V4_TABLE = 9 + 8 + 4
_V4_ENTRY = 16  # usize | csize_flag | crc32 | shard


@pytest.fixture(scope="module")
def v4_frames():
    rng = _rng()
    eng = LZ4Engine(micro_batch=4, shards=3)
    corpora = {
        "multi": b"the quick brown fox " * 9000,                 # 3 blocks
        "mix": (b"pattern! " * 8000
                + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()),
        "uneven": b"sharded fabric " * 20000,                    # 5 blocks / 3
    }
    out = {}
    for name, data in corpora.items():
        frame = eng.compress(data)
        from repro.core import frame_info
        assert frame_info(frame)["version"] == 4
        assert decode_frame(frame) == data
        out[name] = (data, frame)
    return out


@pytest.mark.parametrize("name", ["multi", "mix", "uneven"])
def test_v4_byte_flips_always_detected(v4_frames, name):
    data, frame = v4_frames[name]
    for pos in _flip_positions(len(frame)):
        for mask in (0x01, 0x80, 0xFF):
            mutant = bytearray(frame)
            mutant[pos] ^= mask
            _assert_rejected(bytes(mutant), f"v4 {name}: flip {pos}^{mask:#x}",
                             original=data)


@pytest.mark.parametrize("name", ["multi", "mix", "uneven"])
def test_v4_truncations_always_detected(v4_frames, name):
    _, frame = v4_frames[name]
    n = len(frame)
    cuts = set(range(0, _V4_TABLE + 4 * _V4_ENTRY)) | \
        set(range(0, n, max(1, n // 150))) | {n - 1}
    for cut in sorted(c for c in cuts if c < n):
        _assert_rejected(frame[:cut], f"v4 {name}: truncate to {cut}")


def test_v4_shard_table_flips_detected(v4_frames):
    """Flips confined to the shard COLUMN of the table: shard ids have no
    checksum of their own, so the structural rules (id < shard_count,
    non-decreasing) are what catch them.  A flip that happens to produce
    another valid non-decreasing in-range column (e.g. 0->1 in [0,1,2]) is
    undetectable BY DESIGN — provenance metadata, content untouched — and
    must then decode to exactly the original bytes, never crash."""
    data, frame = v4_frames["multi"]
    from repro.core import frame_info

    info = frame_info(frame)
    count = info["block_count"]
    shard_count = info["shard_count"]
    column = [b["shard"] for b in info["blocks"]]
    rejected = 0
    for i in range(count):
        shard_field = _V4_TABLE + i * _V4_ENTRY + 12
        for delta in (1, 2, 0x80, 0xFF):
            mutated = list(column)
            mutated[i] ^= delta
            mutant = bytearray(frame)
            mutant[shard_field] ^= delta
            still_valid = (
                all(0 <= s < shard_count for s in mutated)
                and all(a <= b for a, b in zip(mutated, mutated[1:]))
            )
            if still_valid:
                assert decode_frame(bytes(mutant)) == data
            else:
                rejected += 1
                _assert_rejected(bytes(mutant),
                                 f"v4: shard[{i}] ^= {delta:#x}")
    assert rejected > 0  # the sweep must actually exercise the reject path


def test_v4_shard_count_mismatch_detected(v4_frames):
    """shard_count header vs table ids: too-small counts make ids
    out-of-range; zero is structurally invalid; huge counts stay valid
    (trailing shards may own no blocks) but must not crash."""
    data, frame = v4_frames["multi"]
    sc_off = 9 + 8
    for bad in (0, 1, 2):  # table holds ids 0..2 -> counts < 3 all invalid
        mutant = bytearray(frame)
        mutant[sc_off: sc_off + 4] = int(bad).to_bytes(4, "little")
        _assert_rejected(bytes(mutant), f"v4: shard_count={bad}")
    big = bytearray(frame)
    big[sc_off: sc_off + 4] = (1000).to_bytes(4, "little")
    assert decode_frame(bytes(big)) == data  # ids 0..2 < 1000: still valid


def test_v4_out_of_order_shards_detected(v4_frames):
    """Shard runs are contiguous by construction; a decreasing shard column
    means a corrupted table or a broken merge — never silent."""
    data, frame = v4_frames["multi"]
    from repro.core import frame_info

    count = frame_info(frame)["block_count"]
    assert count >= 2
    mutant = bytearray(frame)
    # swap the shard ids of the first and last blocks (0 and shards-1)
    first = _V4_TABLE + 12
    last = _V4_TABLE + (count - 1) * _V4_ENTRY + 12
    mutant[first: first + 4], mutant[last: last + 4] = (
        mutant[last: last + 4], mutant[first: first + 4])
    _assert_rejected(bytes(mutant), "v4: out-of-order shard column")


def test_v3_reader_rejects_v4(v4_frames):
    """A deployment pinned to the v3 reader must reject v4 frames outright
    (max_version guard) rather than misparse the wider table."""
    from repro.core import frame_info
    _, frame = v4_frames["multi"]
    with pytest.raises(FrameFormatError, match="max_version"):
        frame_info(frame, max_version=3)
    # and the guard is inclusive: v3 frames still pass it
    v3 = LZ4Engine().compress(b"still v3 " * 100)
    assert frame_info(v3, max_version=3)["version"] == 3


# ---------------------------------------------------------------------------
# Frame v5 (whole-content trailer): one more integrity surface to fuzz.
# ---------------------------------------------------------------------------

# v5 layout: v4 header/table (9 + 8 + 4, 16-byte entries) + 4-byte trailer.
_V5_TABLE = _V4_TABLE
_V5_ENTRY = _V4_ENTRY


@pytest.fixture(scope="module")
def v5_frames():
    rng = _rng()
    eng = LZ4Engine(micro_batch=4, content_crc=True)
    corpora = {
        "multi": b"the quick brown fox " * 9000,                 # 3 blocks
        "mix": (b"pattern! " * 8000
                + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()),
    }
    out = {}
    for name, data in corpora.items():
        frame = eng.compress(data)
        from repro.core import frame_info
        assert frame_info(frame)["version"] == 5
        assert decode_frame(frame) == data
        out[name] = (data, frame)
    return out


@pytest.mark.parametrize("name", ["multi", "mix"])
def test_v5_byte_flips_always_detected(v5_frames, name):
    data, frame = v5_frames[name]
    # _flip_positions covers header/table + strided payload; force the
    # 4 trailer bytes in as well — every trailer flip must be rejected.
    n = len(frame)
    for pos in sorted(set(_flip_positions(n)) | set(range(n - 4, n))):
        for mask in (0x01, 0x80, 0xFF):
            mutant = bytearray(frame)
            mutant[pos] ^= mask
            _assert_rejected(bytes(mutant), f"v5 {name}: flip {pos}^{mask:#x}",
                             original=data)


@pytest.mark.parametrize("name", ["multi", "mix"])
def test_v5_truncations_always_detected(v5_frames, name):
    _, frame = v5_frames[name]
    n = len(frame)
    cuts = set(range(0, _V5_TABLE + 3 * _V5_ENTRY)) | \
        set(range(0, n, max(1, n // 150))) | {n - 4, n - 3, n - 2, n - 1}
    for cut in sorted(c for c in cuts if c < n):
        _assert_rejected(frame[:cut], f"v5 {name}: truncate to {cut}")


def test_v5_trailer_catches_block_swap_per_block_crcs_cannot():
    """The v5 raison d'être: swap two equal-sized blocks' payloads AND
    their table entries.  Every per-block check still passes (each block
    matches its own entry) and the shard column stays flat — only the
    whole-content trailer notices the reordering.  The same mutation on a
    v3 frame of the same content decodes silently to WRONG bytes."""
    from repro.core import block_crc, encode_frame, frame_info

    p0, p1 = b"A" * 40, b"B" * 40  # equal-sized raw blocks, different bytes
    data = p0 + p1
    kw = dict(checksums=[block_crc(p0), block_crc(p1)])

    def swapped(frame):
        info = frame_info(frame)
        b0, b1 = info["blocks"]
        assert b0["csize"] == b1["csize"]
        entry = {3: 12, 5: 16}[info["version"]]
        table = {3: 9 + 8, 5: _V5_TABLE}[info["version"]]
        m = bytearray(frame)
        m[table: table + entry], m[table + entry: table + 2 * entry] = (
            m[table + entry: table + 2 * entry], m[table: table + entry])
        m[b0["offset"]: b0["offset"] + b0["csize"]], \
            m[b1["offset"]: b1["offset"] + b1["csize"]] = (
                m[b1["offset"]: b1["offset"] + b1["csize"]],
                m[b0["offset"]: b0["offset"] + b0["csize"]])
        return bytes(m)

    v3 = encode_frame([p0, p1], [40, 40], [True, True], **kw)
    assert decode_frame(swapped(v3)) == p1 + p0  # silent wrong ORDER on v3

    v5 = encode_frame([p0, p1], [40, 40], [True, True],
                      content_crc=block_crc(data), **kw)
    assert decode_frame(v5) == data
    _assert_rejected(swapped(v5), "v5: equal-size block swap")


def test_v4_reader_rejects_v5(v5_frames):
    """A deployment pinned to the v4 reader must reject v5 frames outright
    rather than treat the trailer as trailing garbage."""
    from repro.core import frame_info
    _, frame = v5_frames["multi"]
    with pytest.raises(FrameFormatError, match="max_version"):
        frame_info(frame, max_version=4)
    with pytest.raises(FrameFormatError, match="max_version"):
        frame_info(frame, max_version=3)


def test_v4_encode_validation():
    """The writer enforces the same invariants the reader checks."""
    from repro.core import block_crc, encode_frame

    payload, usize = b"x" * 10, 10
    crc = block_crc(payload)
    args = dict(checksums=[crc, crc], content_size=True)
    ok = encode_frame([payload] * 2, [usize] * 2, [True] * 2,
                      shards=[0, 1], **args)
    assert decode_frame_serial(ok) == payload * 2
    with pytest.raises(ValueError, match="non-decreasing"):
        encode_frame([payload] * 2, [usize] * 2, [True] * 2,
                     shards=[1, 0], **args)
    with pytest.raises(ValueError, match="out of range"):
        encode_frame([payload] * 2, [usize] * 2, [True] * 2,
                     shards=[0, 5], shard_count=2, **args)
    with pytest.raises(ValueError, match="checksums"):
        encode_frame([payload], [usize], [True], shards=[0])
