"""JAX engine vs numpy golden model: exact per-window record equality,
sequential vs associative scan equivalence, and end-to-end round trips."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decode_block, plan_size
from repro.core.jax_compressor import (
    compress_block_records,
    compress_blocks_records,
    compress_bytes,
    pad_block,
    records_to_plan,
)
from repro.core.schemes import compress_windowed


def _datasets():
    rng = np.random.default_rng(42)
    out = {
        "zeros": b"\x00" * 5000,
        "repeat8": b"abcdefgh" * 700,
        "text": (b"the quick brown fox jumps over the lazy dog. " * 250),
        "low_entropy": rng.integers(0, 4, 20000, dtype=np.uint8).tobytes(),
        "med_entropy": rng.integers(0, 64, 30000, dtype=np.uint8).tobytes(),
        "random": rng.integers(0, 256, 8192, dtype=np.uint8).tobytes(),
        "tiny": b"hello",
        "empty": b"",
        "block_64k": rng.integers(0, 16, 65536, dtype=np.uint8).tobytes(),
        "self_overlap": b"a" * 3000 + b"xyz" + b"a" * 3000,
    }
    return out


def _run_jax(data, hash_bits, max_match, scan_impl="sequential", use_pallas=False):
    buf, n = pad_block(data)
    return compress_block_records(
        jnp.asarray(buf), jnp.int32(n),
        hash_bits=hash_bits, max_match=max_match,
        use_pallas=use_pallas, scan_impl=scan_impl,
    ), n


@pytest.mark.parametrize("name", list(_datasets().keys()))
@pytest.mark.parametrize("hash_bits,max_match", [(8, 36), (12, 36), (6, 12), (10, 68)])
def test_jax_matches_golden(name, hash_bits, max_match):
    data = _datasets()[name]
    golden = compress_windowed(data, hash_bits=hash_bits, max_match=max_match)
    rec, n = _run_jax(data, hash_bits, max_match)
    W = len(golden.emit)
    emit = np.asarray(rec.emit)[:W]
    np.testing.assert_array_equal(emit, golden.emit, err_msg=f"{name} emit")
    np.testing.assert_array_equal(np.asarray(rec.pos)[:W][emit], golden.pos[golden.emit])
    np.testing.assert_array_equal(np.asarray(rec.length)[:W][emit], golden.length[golden.emit])
    np.testing.assert_array_equal(np.asarray(rec.offset)[:W][emit], golden.offset[golden.emit])
    # windows beyond the golden range never emit
    assert not np.asarray(rec.emit)[W:].any()
    # analytic size == exact plan size
    assert int(rec.size) == plan_size(golden.sequences)


@pytest.mark.parametrize("name", list(_datasets().keys()))
def test_associative_equals_sequential(name):
    data = _datasets()[name]
    rec_s, _ = _run_jax(data, 8, 36, scan_impl="sequential")
    rec_a, _ = _run_jax(data, 8, 36, scan_impl="associative")
    np.testing.assert_array_equal(np.asarray(rec_s.emit), np.asarray(rec_a.emit))
    np.testing.assert_array_equal(np.asarray(rec_s.pos), np.asarray(rec_a.pos))
    np.testing.assert_array_equal(np.asarray(rec_s.length), np.asarray(rec_a.length))
    assert int(rec_s.size) == int(rec_a.size)


@pytest.mark.parametrize("scan_impl", ["sequential", "associative"])
def test_pallas_path_equals_ref_path(scan_impl):
    data = _datasets()["low_entropy"]
    rec_r, _ = _run_jax(data, 8, 36, scan_impl=scan_impl, use_pallas=False)
    rec_p, _ = _run_jax(data, 8, 36, scan_impl=scan_impl, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(rec_r.emit), np.asarray(rec_p.emit))
    np.testing.assert_array_equal(np.asarray(rec_r.length), np.asarray(rec_p.length))
    assert int(rec_r.size) == int(rec_p.size)


@pytest.mark.parametrize("name", list(_datasets().keys()))
def test_roundtrip_via_encoder(name):
    data = _datasets()[name]
    rec, n = _run_jax(data, 8, 36)
    from repro.core import encode_block

    plan = records_to_plan(rec, n)
    assert decode_block(encode_block(data, plan)) == data
    assert len(encode_block(data, plan)) == int(rec.size)


def test_compress_bytes_multiblock():
    rng = np.random.default_rng(7)
    data = rng.integers(0, 8, 200_000, dtype=np.uint8).tobytes()
    blocks = compress_bytes(data)
    restored = b"".join(decode_block(b) for b in blocks)
    assert restored == data
    assert sum(len(b) for b in blocks) < len(data)


def test_batched_blocks_vmap():
    rng = np.random.default_rng(9)
    datas = [rng.integers(0, 4, 65536, dtype=np.uint8).tobytes() for _ in range(3)]
    bufs, ns = zip(*(pad_block(d) for d in datas))
    recs = compress_blocks_records(jnp.asarray(np.stack(bufs)), jnp.asarray(ns, jnp.int32))
    for i, d in enumerate(datas):
        single, _ = _run_jax(d, 8, 36)
        assert int(recs.size[i]) == int(single.size)


@pytest.mark.parametrize("name", ["low_entropy", "zeros", "text", "random", "block_64k"])
def test_scatter_candidates_equal_sort(name):
    """Beyond-paper scatter-max candidate resolution is bit-identical."""
    data = _datasets()[name]
    buf, n = pad_block(data)
    a = compress_block_records(jnp.asarray(buf), jnp.int32(n), candidate_impl="sort")
    for impl in ("scatter", "sortkey"):
        b = compress_block_records(jnp.asarray(buf), jnp.int32(n), candidate_impl=impl)
        np.testing.assert_array_equal(np.asarray(a.emit), np.asarray(b.emit))
        np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
        np.testing.assert_array_equal(np.asarray(a.length), np.asarray(b.length))
        np.testing.assert_array_equal(np.asarray(a.offset), np.asarray(b.offset))
        assert int(a.size) == int(b.size)
