"""Unit tests for the numpy golden models, encoder and decoder."""
import numpy as np
import pytest

from repro.core import (
    Sequence,
    compress_greedy,
    compress_windowed,
    compress_windowed_multi,
    decode_block,
    encode_block,
    plan_coverage,
    plan_size,
)
from repro.core.reference import fib_hash, le32_words, prev_same_hash
from repro.core.schemes import window_candidates


def roundtrip(data: bytes, plan) -> None:
    block = encode_block(data, plan)
    assert decode_block(block) == data
    assert len(block) == plan_size(plan)


class TestPrimitives:
    def test_le32_words(self):
        data = np.array([1, 2, 3, 4, 5], dtype=np.uint8)
        w = le32_words(data)
        assert w.tolist() == [0x04030201, 0x05040302]

    def test_fib_hash_range(self):
        words = np.arange(1000, dtype=np.uint32) * 7919
        for bits in (6, 8, 12, 13):
            h = fib_hash(words, bits)
            assert h.min() >= 0 and h.max() < (1 << bits)

    def test_prev_same_hash(self):
        h = np.array([3, 1, 3, 3, 1, 2])
        assert prev_same_hash(h).tolist() == [-1, -1, 0, 2, 1, -1]

    def test_window_candidates_window_granular(self):
        # pws=4: candidates must come from strictly earlier windows.
        h = np.array([5, 5, 5, 5, 5, 9, 5, 5])
        cand = window_candidates(h, pws=4)
        # Positions 0-3 (window 0): no earlier window -> -1.
        assert cand[:4].tolist() == [-1, -1, -1, -1]
        # Positions 4,6,7 (window 1): latest hash-5 position in window 0 is 3.
        assert cand[4] == 3 and cand[6] == 3 and cand[7] == 3
        assert cand[5] == -1  # hash 9 never seen before


class TestGreedy:
    def test_empty(self):
        plan = compress_greedy(b"")
        assert plan == [Sequence(0, 0)]
        roundtrip(b"", plan)

    def test_incompressible_short(self):
        data = bytes(range(13))
        plan = compress_greedy(data)
        assert plan_coverage(plan) == len(data)
        roundtrip(data, plan)

    def test_repetitive_compresses(self):
        data = b"abcdefgh" * 512
        plan = compress_greedy(data, hash_bits=12)
        assert plan_size(plan) < len(data) // 10
        roundtrip(data, plan)

    def test_overlapping_match(self):
        data = b"a" * 1000
        plan = compress_greedy(data)
        roundtrip(data, plan)  # offset < match_len requires byte-wise copy

    def test_max_match_caps_length(self):
        data = b"x" * 2000
        plan = compress_greedy(data, max_match=36)
        assert all(s.match_len <= 36 for s in plan)
        roundtrip(data, plan)

    def test_capped_not_much_worse(self):
        data = (b"the quick brown fox jumps over the lazy dog. " * 200)[:8000]
        free = plan_size(compress_greedy(data, hash_bits=12))
        capped = plan_size(compress_greedy(data, hash_bits=12, max_match=36))
        assert capped >= free  # cap can only hurt
        assert capped < len(data)  # still compresses

    def test_end_of_block_rules(self):
        data = b"abcd" * 100
        plan = compress_greedy(data)
        assert plan[-1].match_len == 0
        for s in plan[:-1]:
            assert s.lit_start + s.lit_len + s.match_len <= len(data) - 5
            assert s.lit_start + s.lit_len <= len(data) - 12


class TestWindowed:
    def test_empty_and_tiny(self):
        for data in (b"", b"a", b"abc", b"abcdefghijk"):
            res = compress_windowed(data)
            assert plan_coverage(res.sequences) == len(data)
            roundtrip(data, res.sequences)

    def test_repetitive(self):
        data = b"hello world, " * 600
        res = compress_windowed(data, hash_bits=12)
        assert plan_size(res.sequences) < len(data) // 3
        roundtrip(data, res.sequences)

    def test_bounded_match_length(self):
        data = b"z" * 4000
        res = compress_windowed(data, max_match=36)
        assert res.length.max() <= 36
        roundtrip(data, res.sequences)

    def test_single_match_per_window(self):
        data = (b"abcdefgh12345678" * 400)[:6400]
        res = compress_windowed(data, hash_bits=12)
        # at most one match per window by construction
        assert res.emit.dtype == bool
        roundtrip(data, res.sequences)

    def test_unbounded_variant(self):
        data = b"q" * 3000
        res = compress_windowed(data, max_match=None)
        assert res.length.max() > 36  # unbounded extension reaches far
        roundtrip(data, res.sequences)

    def test_matches_do_not_overlap(self):
        rng = np.random.default_rng(0)
        base = rng.integers(0, 4, 2048, dtype=np.uint8).tobytes()
        data = base + base[:1024] + base
        res = compress_windowed(data, hash_bits=10)
        end = 0
        for w in np.nonzero(res.emit)[0]:
            assert res.pos[w] >= end
            end = res.pos[w] + res.length[w]
        roundtrip(data, res.sequences)

    def test_ratio_ordering_schemes(self):
        """Paper Tables I-III ordering: greedy >= single-match >= combined."""
        data = (b"the cat sat on the mat and the dog sat on the log. " * 300)[:12000]
        greedy = plan_size(compress_greedy(data, hash_bits=10))
        single = plan_size(compress_windowed(data, hash_bits=10, max_match=None).sequences)
        combined = plan_size(compress_windowed(data, hash_bits=10, max_match=36).sequences)
        assert greedy <= single <= combined

    def test_multi_match_windowed(self):
        data = b"abcd1234" * 500
        res = compress_windowed_multi(data, hash_bits=12)
        roundtrip(data, res.sequences)
        assert res.matches_per_window.sum() >= 1


class TestEncoderDecoder:
    def test_long_literal_run_extension_bytes(self):
        data = bytes(np.random.default_rng(1).integers(0, 256, 700, dtype=np.uint8))
        plan = [Sequence(0, 700)]
        roundtrip(data, plan)

    def test_long_match_extension_bytes(self):
        data = b"m" * 5000
        plan = compress_greedy(data)
        assert any(s.match_len > 270 for s in plan)
        roundtrip(data, plan)

    def test_decoder_rejects_bad_offset(self):
        import pytest
        from repro.core import LZ4FormatError
        # token: 1 literal then match with offset 9 > produced output
        bad = bytes([0x10, ord("a"), 0x09, 0x00])
        with pytest.raises(LZ4FormatError):
            decode_block(bad)

    def test_encoder_rejects_bad_plan(self):
        with pytest.raises(ValueError):
            encode_block(b"abcdef", [Sequence(0, 3)])  # does not cover block
