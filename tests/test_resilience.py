"""Resilience layer: retry policies, seeded fault injection, the FrameError
hierarchy, tolerant frame scanning, salvage decode across all four executors
(the seeded chaos matrix), crash-consistent checkpoints, and the salvage
paths through checkpoint restore and serving cache restore.

The chaos matrix here is the acceptance gate: over a fixed seed matrix and
every decode executor, injected corruption is NEVER silent, salvage recovers
every undamaged block, and frame-v6 parity reconstructs any single damaged
block per group byte-identically.
"""
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.checkpoint import checkpoint as ckpt
from repro.core import (FrameReader, LZ4DecodeEngine, LZ4Engine, block_crc,
                        decode_frame, frame_info, scan_frame)
from repro.core.decoder import LZ4FormatError
from repro.core.frame import FrameFormatError
from repro.resilience import FrameError, RetryPolicy
from repro.resilience import retry as retry_mod
from repro.resilience.inject import (FaultInjector, InjectedCrash,
                                     corrupt_frame_block, crash_point,
                                     flip_bits, frame_payload_region,
                                     install, io_point, truncate)
from repro.resilience.salvage import SalvageReport, salvage_frame
from repro.serving.engine import (OffloadedCacheReader, offload_cache,
                                  restore_cache)


def _rng():
    return np.random.default_rng(20260809)


def _payload():
    """Compressible + incompressible mix -> both LZ4 and raw-stored blocks."""
    return (b"salvage every undamaged block " * 5000
            + _rng().integers(0, 256, 70000, np.uint8).tobytes())


EXECUTORS = ["serial", "thread", "process", "device"]


@pytest.fixture(scope="module")
def engines():
    """One decode engine per executor, shared across the chaos matrix
    (process pools are expensive to spin per-test)."""
    return {
        "serial": LZ4DecodeEngine(executor="serial"),
        "thread": LZ4DecodeEngine(executor="thread", workers=2),
        "process": LZ4DecodeEngine(executor="process", workers=2),
        "device": LZ4DecodeEngine(executor="device"),
    }


@pytest.fixture
def enabled_obs():
    was = obs.is_enabled()
    obs.configure(enabled=True)
    obs.reset()
    yield obs
    obs.reset()
    obs.configure(enabled=was)


# ---------------------------------------------------------------------------
# retry: decorrelated jitter, budgets, deadlines, the RestartPolicy alias
# ---------------------------------------------------------------------------

class TestRetry:
    def test_backoffs_seeded_and_capped(self):
        pol = RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.5, seed=7)
        a = list(pol.backoffs())
        b = list(RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.5,
                             seed=7).backoffs())
        assert a == b and len(a) == 5
        assert all(0.01 <= d <= 0.5 for d in a)
        # Decorrelated jitter, not a deterministic ladder.
        assert a != list(RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.5,
                                     seed=8).backoffs())

    def test_call_recovers_from_transient_failures(self):
        calls, sleeps, retries = [], [], []
        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"
        out = retry_mod.call(
            flaky, policy=RetryPolicy(max_attempts=4, seed=0),
            sleep=sleeps.append,
            on_retry=lambda n, e, d: retries.append((n, str(e))))
        assert out == "ok" and len(calls) == 3 and len(sleeps) == 2
        assert retries == [(1, "transient"), (2, "transient")]

    def test_call_raises_after_budget(self):
        calls, sleeps = [], []
        def doomed():
            calls.append(1)
            raise OSError(f"fail {len(calls)}")
        with pytest.raises(OSError, match="fail 3"):
            retry_mod.call(doomed, policy=RetryPolicy(max_attempts=3, seed=0),
                           sleep=sleeps.append)
        assert len(calls) == 3 and len(sleeps) == 2

    def test_non_transient_propagates_unretried(self):
        calls = []
        def bad():
            calls.append(1)
            raise ValueError("corrupt — not transient")
        with pytest.raises(ValueError):
            retry_mod.call(bad, policy=RetryPolicy(max_attempts=5, seed=0),
                           sleep=lambda d: None)
        assert len(calls) == 1

    def test_deadline_abandons_retries(self):
        clock = iter([0.0, 100.0]).__next__  # second look: way past deadline
        calls = []
        def doomed():
            calls.append(1)
            raise OSError("x")
        with pytest.raises(OSError):
            retry_mod.call(doomed,
                           policy=RetryPolicy(max_attempts=10, deadline_s=1.0,
                                              seed=0),
                           sleep=lambda d: None, clock=clock)
        assert len(calls) == 1  # next sleep would cross the deadline

    def test_retrying_decorator(self):
        state = {"n": 0}
        @retry_mod.retrying(RetryPolicy(max_attempts=3, seed=1),
                            sleep=lambda d: None)
        def sometimes(x):
            state["n"] += 1
            if state["n"] == 1:
                raise OSError("once")
            return x * 2
        assert sometimes(21) == 42 and state["n"] == 2

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(base_s=0.5, cap_s=0.1)
        with pytest.raises(ValueError):
            RetryPolicy(multiplier=0.5)

    def test_restart_policy_promoted_with_alias(self):
        # The deprecation alias at the old path IS the promoted class.
        from repro.distributed.fault import RestartPolicy as OldPath
        from repro.resilience.retry import RestartPolicy as NewPath
        assert OldPath is NewPath
        pol = OldPath(max_failures=2, backoff_s=0.5)
        assert pol.record_failure() == 0.5
        assert pol.record_failure() == 1.0
        with pytest.raises(RuntimeError, match="giving up after 2 failures"):
            pol.record_failure()


# ---------------------------------------------------------------------------
# fault injection: seeded corruption helpers + armed crash / I/O points
# ---------------------------------------------------------------------------

class TestInject:
    def test_flip_bits_deterministic(self):
        data = bytes(range(256)) * 4
        a = flip_bits(data, seed=3, n=5)
        assert a == flip_bits(data, seed=3, n=5)
        assert a != data and len(a) == len(data)
        diff = sum(bin(x ^ y).count("1") for x, y in zip(a, data))
        assert diff == 5
        assert flip_bits(data, seed=4, n=5) != a

    def test_flip_bits_respects_region(self):
        data = b"\x00" * 100
        out = flip_bits(data, seed=0, n=8, start=40, end=50)
        assert out[:40] == data[:40] and out[50:] == data[50:]
        assert out[40:50] != data[40:50]
        with pytest.raises(ValueError, match="bad flip region"):
            flip_bits(data, seed=0, start=90, end=200)

    def test_truncate_seeded(self):
        data = b"x" * 1000
        out = truncate(data, seed=5)
        assert out == truncate(data, seed=5)
        assert 1 <= len(out) < len(data)
        with pytest.raises(ValueError):
            truncate(b"x", seed=0)

    def test_corrupt_frame_block_targets_payload_only(self):
        frame = LZ4Engine().compress(_payload())
        start, end = frame_payload_region(frame, 1)
        bad = corrupt_frame_block(frame, 1, seed=9)
        assert bad[:start] == frame[:start] and bad[end:] == frame[end:]
        assert frame_info(bad)["block_count"] == frame_info(frame)["block_count"]
        with pytest.raises(FrameFormatError):
            decode_frame(bad)

    def test_crash_fires_exactly_once(self):
        inj = FaultInjector(seed=0, crash_at="seam.x")
        with install(inj):
            crash_point("seam.other")  # not the target
            with pytest.raises(InjectedCrash, match="seam.x"):
                crash_point("seam.x")
            crash_point("seam.x")  # disarmed after firing
        assert inj.crashes == ["seam.x"]

    def test_io_faults_then_recovery(self):
        inj = FaultInjector(seed=0, fail={"op.read": 2})
        with install(inj):
            for _ in range(2):
                with pytest.raises(OSError, match="injected transient"):
                    io_point("op.read")
            io_point("op.read")  # budget spent: passes
        assert inj.io_faults == ["op.read", "op.read"]

    def test_nested_install_rejected(self):
        with install(FaultInjector()):
            with pytest.raises(RuntimeError, match="already installed"):
                install(FaultInjector()).__enter__()

    def test_unarmed_points_are_noops(self):
        crash_point("anything")
        io_point("anything")


# ---------------------------------------------------------------------------
# FrameError hierarchy: one handler for frame + checkpoint corruption
# ---------------------------------------------------------------------------

class TestErrors:
    def test_hierarchy(self):
        assert issubclass(FrameFormatError, LZ4FormatError)
        assert issubclass(LZ4FormatError, FrameError)
        assert issubclass(LZ4FormatError, ValueError)
        assert issubclass(ckpt.CheckpointError, FrameError)
        assert issubclass(ckpt.CheckpointError, RuntimeError)

    def test_attrs_and_pickling(self):
        e = FrameFormatError("block 3: checksum mismatch",
                             block_index=3, cause="crc")
        assert e.block_index == 3 and e.cause == "crc"
        e2 = pickle.loads(pickle.dumps(e))  # process-pool boundary
        assert type(e2) is FrameFormatError
        assert str(e2) == str(e)
        assert e2.block_index == 3 and e2.cause == "crc"

    def test_real_errors_carry_cause(self):
        frame = LZ4Engine().compress(_payload())
        bad = corrupt_frame_block(frame, 0, seed=1)
        with pytest.raises(FrameError) as ei:
            decode_frame(bad)
        assert ei.value.cause in ("crc", "size", "parse")
        with pytest.raises(FrameError) as ei:
            frame_info(frame[:10])
        assert ei.value.cause == "truncated"


# ---------------------------------------------------------------------------
# scan_frame: tolerant structure parse
# ---------------------------------------------------------------------------

class TestScanFrame:
    def test_intact_frame_is_complete(self):
        frame = LZ4Engine(parity_group=2).compress(_payload())
        info = scan_frame(frame)
        assert info["complete"] and info["notes"] == []
        assert all(b["ok"] for b in info["blocks"])
        assert all(p["ok"] for p in info["parity"])

    def test_truncated_frame_keeps_readable_prefix(self):
        frame = LZ4Engine().compress(_payload())
        whole = frame_info(frame)
        cut = whole["blocks"][2]["offset"] + 10  # mid-payload of block 2
        info = scan_frame(frame[:cut])
        assert not info["complete"]
        assert info["block_count"] == whole["block_count"]  # header claim
        oks = [b["ok"] for b in info["blocks"]]
        assert oks[:2] == [True, True] and not any(oks[2:])
        assert all(b["note"] for b in info["blocks"] if not b["ok"])

    def test_unsalvageable_raises(self):
        with pytest.raises(FrameFormatError):
            scan_frame(b"nope")
        frame = LZ4Engine().compress(b"x" * 100)
        with pytest.raises(FrameFormatError):
            scan_frame(b"XXXX" + frame[4:])  # bad magic


# ---------------------------------------------------------------------------
# the seeded chaos matrix: all four executors, zero silent corruption
# ---------------------------------------------------------------------------

class TestSalvageMatrix:
    @pytest.mark.parametrize("executor", EXECUTORS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_parity_reconstructs_single_damage(self, engines, executor, seed):
        """v6 parity: any single damaged block per group comes back
        byte-identical, on every executor, for every seed."""
        data = _payload()
        frame = LZ4Engine(parity_group=4).compress(data)
        n = frame_info(frame)["block_count"]
        victim = seed % n
        bad = corrupt_frame_block(frame, victim, seed=seed, n=3)
        rep = engines[executor].salvage(bad)
        assert rep.complete and rep.lost == [] and rep.holes == []
        assert rep.reconstructed == [victim]
        assert rep.data == data  # byte-identical
        assert rep.content_crc_ok is True
        assert "reconstructed from parity" in rep.errors[victim]

    @pytest.mark.parametrize("executor", EXECUTORS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_salvage_recovers_every_undamaged_block(self, engines, executor,
                                                    seed):
        """No parity: the damaged block is LOST (reported, zero-filled) and
        every other block is recovered exactly — nothing silent."""
        data = _payload()
        frame = LZ4Engine().compress(data)
        info = frame_info(frame)
        n = info["block_count"]
        victim = seed % n
        bad = corrupt_frame_block(frame, victim, seed=seed, n=3)
        rep = engines[executor].salvage(bad)
        assert rep.lost == [victim] and rep.reconstructed == []
        assert rep.ok == [i for i in range(n) if i != victim]
        assert victim in rep.errors
        # The hole covers exactly the victim's decompressed span, zeroed.
        start = sum(b["usize"] for b in info["blocks"][:victim])
        span = info["blocks"][victim]["usize"]
        assert rep.holes == [(start, start + span)]
        assert rep.data[start: start + span] == b"\x00" * span
        # Every byte OUTSIDE the hole matches the original exactly.
        assert rep.data[:start] == data[:start]
        assert rep.data[start + span:] == data[start + span:]
        assert len(rep.data) == len(data)

    def test_two_damaged_blocks_in_group_stay_lost(self, engines):
        data = _payload()
        frame = LZ4Engine(parity_group=4).compress(data)
        bad = corrupt_frame_block(frame, 0, seed=0, n=3)
        bad = corrupt_frame_block(bad, 1, seed=1, n=3)
        rep = engines["serial"].salvage(bad)
        assert rep.lost == [0, 1] and rep.reconstructed == []
        assert "damaged" in rep.errors[0]  # why parity could not save it

    def test_damaged_parity_payload_cannot_reconstruct(self, engines):
        data = _payload()
        frame = LZ4Engine(parity_group=4).compress(data)
        info = frame_info(frame)
        bad = corrupt_frame_block(frame, 0, seed=0, n=3)
        p = info["parity"][0]
        bad = flip_bits(bad, seed=2, n=3, start=p["offset"],
                        end=p["offset"] + p["plen"])
        rep = engines["serial"].salvage(bad)
        assert rep.lost == [0]
        assert "failed its CRC" in rep.errors[0]

    def test_truncated_frame_salvages_prefix(self, engines):
        data = _payload()
        frame = LZ4Engine().compress(data)
        info = frame_info(frame)
        cut = info["blocks"][2]["offset"] + 10
        rep = engines["thread"].salvage(frame[:cut])
        assert rep.ok == [0, 1]
        two = sum(b["usize"] for b in info["blocks"][:2])
        assert rep.data[:two] == data[:two]
        assert len(rep.data) == len(data)  # zero-extended to content_size
        assert rep.data[two:] == b"\x00" * (len(data) - two)
        assert rep.holes == [(two, len(data))]

    def test_counters_pinned(self, engines, enabled_obs):
        """The CI chaos leg pins these exact counts."""
        data = _payload()
        n = frame_info(LZ4Engine().compress(data))["block_count"]
        bad_v6 = corrupt_frame_block(
            LZ4Engine(parity_group=4).compress(data), 1, seed=0, n=3)
        bad_v3 = corrupt_frame_block(LZ4Engine().compress(data), 1,
                                     seed=0, n=3)
        engines["serial"].salvage(bad_v6)
        engines["serial"].salvage(bad_v3)
        c = obs.snapshot()["metrics"]["counters"]
        assert c["resilience.salvage_calls"] == 2
        assert c["resilience.reconstructed_blocks"] == 1   # parity save
        assert c["resilience.lost_blocks"] == 1            # no-parity loss
        assert c["resilience.salvaged_blocks"] == 2 * (n - 1)

    def test_decode_engine_on_error_salvage(self):
        data = _payload()
        bad = corrupt_frame_block(
            LZ4Engine(parity_group=4).compress(data), 2, seed=0, n=3)
        with pytest.raises(FrameFormatError):
            LZ4DecodeEngine().decode(bad)
        eng = LZ4DecodeEngine(on_error="salvage")
        assert eng.decode(bad) == data  # parity made it whole
        assert eng.last_salvage is not None
        assert eng.last_salvage.reconstructed == [2]
        with pytest.raises(ValueError, match="on_error"):
            LZ4DecodeEngine(on_error="ignore")

    def test_frame_reader_salvage(self):
        data = _payload()
        frame = LZ4Engine().compress(data)
        info = frame_info(frame)
        bad = corrupt_frame_block(frame, 2, seed=0, n=3)
        rep = FrameReader(bad).salvage()  # strict readers can still salvage
        assert isinstance(rep, SalvageReport) and rep.lost == [2]
        # Tolerant reader on a TRUNCATED frame: reads inside the readable
        # prefix still work (strict construction would refuse the frame).
        cut = info["blocks"][2]["offset"] + 10
        rdr = FrameReader(frame[:cut], on_error="salvage")
        assert rdr.block_count == info["block_count"]  # table fully readable
        assert rdr.read_range(100, 50) == data[100:150]
        with pytest.raises(FrameFormatError):
            FrameReader(frame[:cut])


# ---------------------------------------------------------------------------
# crash-consistent checkpoints: kill-in-the-middle, digests, retries, salvage
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32),
        "w": jnp.asarray(np.zeros((40_000,)), jnp.float32),  # compressible
        "r": jnp.asarray(rng.integers(0, 255, 5000), jnp.uint8),
    }


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestCheckpointCrash:
    @pytest.mark.parametrize("seam", ["checkpoint.data",
                                      "checkpoint.manifest",
                                      "checkpoint.rename"])
    def test_kill_in_the_middle_never_tears_a_step(self, chaos, tmp_path,
                                                   seam):
        """A writer killed at any pre-rename seam leaves the previous step
        fully restorable and the next save heals the debris."""
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        inj = chaos(seed=3, crash_at=seam)
        with pytest.raises(InjectedCrash):
            ckpt.save(str(tmp_path), 2, _tree(seed=1))
        assert inj.crashes == [seam]
        # The torn attempt is invisible to every discovery/restore path.
        assert ckpt.latest_step(str(tmp_path)) == 1
        restored, step = ckpt.restore_with_fallback(str(tmp_path), tree)
        assert step == 1
        _trees_equal(tree, restored)
        # Retrying the save (injector disarmed after firing) clears the
        # stale .tmp and lands step 2.
        tree2 = _tree(seed=1)
        ckpt.save(str(tmp_path), 2, tree2)
        assert not os.path.exists(tmp_path / "ckpt_2.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 2
        restored, step = ckpt.restore(str(tmp_path), 2, tree2)
        _trees_equal(tree2, restored)

    def test_crash_after_rename_keeps_new_step(self, chaos, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        chaos(seed=0, crash_at="checkpoint.cleanup")
        with pytest.raises(InjectedCrash):
            ckpt.save(str(tmp_path), 2, tree)
        # Rename already happened: the new step IS the durable state.
        assert ckpt.latest_step(str(tmp_path)) == 2
        restored, step = ckpt.restore(str(tmp_path), 2, tree)
        assert step == 2

    def test_torn_data_bin_rejected_by_size_digest(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        data = tmp_path / "ckpt_1" / "data.bin"
        data.write_bytes(data.read_bytes()[:-7])
        with pytest.raises(ckpt.CheckpointError,
                           match="data.bin is") as ei:
            ckpt.restore(str(tmp_path), 1, tree)
        assert ei.value.cause == "truncated"

    def test_flipped_bytes_rejected_by_stored_digest(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        data = tmp_path / "ckpt_1" / "data.bin"
        raw = bytearray(data.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        data.write_bytes(bytes(raw))
        with pytest.raises(ckpt.CheckpointError,
                           match="failed their digest"):
            ckpt.restore(str(tmp_path), 1, tree)

    def test_transient_io_retried(self, chaos, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        inj = chaos(seed=0, fail={"checkpoint.open": 1, "checkpoint.read": 2})
        restored, step = ckpt.restore(str(tmp_path), 1, tree)
        assert step == 1
        _trees_equal(tree, restored)
        assert sorted(inj.io_faults) == ["checkpoint.open", "checkpoint.read",
                                         "checkpoint.read"]

    def test_restore_salvage_reports_damage(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        data = tmp_path / "ckpt_1" / "data.bin"
        raw = data.read_bytes()
        data.write_bytes(flip_bits(raw, seed=4, n=3))
        # Strict restore refuses (stored digest) ...
        with pytest.raises(ckpt.CheckpointError):
            ckpt.restore(str(tmp_path), 1, tree)
        # ... salvage restore keeps shapes and ACCOUNTS for the damage.
        report = {}
        restored, step = ckpt.restore(str(tmp_path), 1, tree,
                                      on_error="salvage", report=report)
        assert step == 1
        assert report["zero_filled"] or report["crc_mismatch"]  # never silent
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.asarray(a).shape == np.asarray(b).shape

    def test_fallback_steps_past_corrupt_checkpoint(self, tmp_path,
                                                    enabled_obs):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, _tree(seed=1))
        data = tmp_path / "ckpt_2" / "data.bin"
        data.write_bytes(flip_bits(data.read_bytes(), seed=0, n=3))
        restored, step = ckpt.restore_with_fallback(str(tmp_path), tree)
        assert step == 1
        _trees_equal(tree, restored)
        c = obs.snapshot()["metrics"]["counters"]
        assert c["checkpoint.fallback_steps"] == 1
        assert c["checkpoint.fallback_restores"] == 1
        # Corrupt steps are skipped, never deleted (post-mortem salvage).
        assert (tmp_path / "ckpt_2").exists()

    def test_fallback_exhausted_raises(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        data = tmp_path / "ckpt_1" / "data.bin"
        data.write_bytes(data.read_bytes()[:-5])
        with pytest.raises(ckpt.CheckpointError,
                           match="no valid checkpoint found"):
            ckpt.restore_with_fallback(str(tmp_path), tree)


# ---------------------------------------------------------------------------
# serving: cache restore salvage + reader salvage
# ---------------------------------------------------------------------------

def _cache():
    rng = np.random.default_rng(11)
    return {"k": jnp.asarray(rng.normal(0, 1, (40, 2048)), jnp.float32),
            "v": jnp.asarray(np.zeros((30, 2048)), jnp.float32)}


class TestServingSalvage:
    def test_restore_cache_salvage_without_parity(self):
        cache = _cache()
        blob, _ = offload_cache(cache)
        blob[1][0]["frame"] = corrupt_frame_block(blob[1][0]["frame"], 0,
                                                  seed=0, n=3)
        with pytest.raises(FrameFormatError):
            restore_cache(blob)
        report = {}
        restored = restore_cache(blob, on_error="salvage", report=report)
        assert set(report) == {0} and report[0].lost == [0]
        # Undamaged leaf restores exactly; damaged leaf keeps its shape.
        np.testing.assert_array_equal(np.asarray(cache["v"]),
                                      np.asarray(restored["v"]))
        assert np.asarray(restored["k"]).shape == (40, 2048)

    def test_restore_cache_salvage_with_parity(self):
        """Re-framed with v6 parity, a damaged cache leaf restores
        byte-identically through the serving path."""
        cache = _cache()
        blob, _ = offload_cache(cache)
        raw = np.asarray(cache["k"]).tobytes()
        frame = LZ4Engine(parity_group=4).compress(raw)
        blob[1][0]["frame"] = corrupt_frame_block(frame, 1, seed=0, n=3)
        report = {}
        restored = restore_cache(blob, on_error="salvage", report=report)
        assert report[0].reconstructed == [1] and report[0].complete
        for k in cache:
            np.testing.assert_array_equal(np.asarray(cache[k]),
                                          np.asarray(restored[k]))

    def test_restore_cache_salvage_to_device(self):
        cache = _cache()
        blob, _ = offload_cache(cache)
        raw = np.asarray(cache["k"]).tobytes()
        frame = LZ4Engine(parity_group=4).compress(raw)
        blob[1][0]["frame"] = corrupt_frame_block(frame, 0, seed=1, n=3)
        report = {}
        restored = restore_cache(blob, to_device=True, on_error="salvage",
                                 report=report)
        assert report[0].complete
        np.testing.assert_array_equal(np.asarray(cache["k"]),
                                      np.asarray(restored["k"]))

    def test_offloaded_reader_salvage_leaf(self):
        cache = _cache()
        blob, _ = offload_cache(cache)
        blob[1][0]["frame"] = corrupt_frame_block(blob[1][0]["frame"], 1,
                                                  seed=2, n=3)
        with pytest.raises(ValueError, match="on_error"):
            OffloadedCacheReader(blob, on_error="nope")
        rdr = OffloadedCacheReader(blob, on_error="salvage")
        rep = rdr.salvage_leaf(0)
        assert rep.lost == [1]
        shape, dtype = rdr.leaf_meta(0)
        arr = np.frombuffer(rep.data, dtype).reshape(shape)
        assert arr.shape == (40, 2048)
        # Undamaged leaf reads stay exact through the same reader.
        np.testing.assert_array_equal(
            rdr.read_leaf(1, start=64, count=32),
            np.asarray(cache["v"]).reshape(-1)[64:96])
