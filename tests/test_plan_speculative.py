"""Speculative in-graph decode planning (PR-9 acceptance surface).

The speculative planner (`kernels.plan_speculative` Pallas kernel +
`kernels.ref.plan_fields_ref` jnp twin, validated/compacted by
`kernels.ops.plan_speculative`) decodes a CANDIDATE sequence header at
every byte offset and chain-selects the one real parse — replacing the
host `plan_block_fast` O(n) walk on the device decode path.  Pinned here:

  * plan bit-identity: the compacted device plan (literal/match columns,
    counts, out_size) equals `to_device_plan(plan_block(...))` — the
    serial parser stays the oracle — on adversarial corpora: 0xFF-run
    extension boundaries, RLE offset-1 chains, literals-only finals,
    hand-built token streams;
  * rejection identity: truncated and lying streams fail with the SAME
    error message the host planner raises, position-priority included;
  * kernel twin identity: the Pallas kernel's raw field arrays equal the
    jnp reference bit for bit;
  * the fused `plan_decode` graph (plan + gather + CRC in one dispatch)
    reproduces payload bytes and `block_crc`;
  * `LZ4DecodeEngine(executor="device", plan_on_device=True)` decodes
    bit-identically to the serial oracle with ZERO host-planner calls and
    `host_bytes == 0` on the to-device paths — planning included;
  * the sharded fabric (`decode_items_sharded` under shard_map) takes the
    same in-graph path on a multi-device mesh (subprocess leg).
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    DevicePlanCaps,
    FrameFormatError,
    LZ4DecodeEngine,
    LZ4Engine,
    Sequence,
    decode_frame_serial,
    encode_block,
    plan_block,
    plan_block_fast,
    to_device_plan,
)
from repro.core.decode_engine import _spec_err_message
from repro.core.decoder import LZ4FormatError
from repro.core.lz4_types import MAX_BLOCK

_CAPS = DevicePlanCaps()


def _rng():
    return np.random.default_rng(20260808)


def _encode_oracle(data: bytes) -> bytes:
    from repro.core import compress_windowed

    res = compress_windowed(data, hash_bits=8, max_match=36)
    return encode_block(data, res.sequences)


def _adversarial_corpus() -> dict[str, bytes]:
    """Valid blocks hitting every parser edge: returns name -> block."""
    rng = _rng()
    out = {}
    # 0xFF-run boundaries of the LITERAL length extension: 15 needs the
    # first extension byte, 270 the first 0xFF run byte, 525 two runs.
    for ll in (1, 14, 15, 16, 269, 270, 271, 524, 525):
        data = bytes(rng.integers(0, 256, ll, np.uint8))
        out[f"lit_{ll}"] = encode_block(data, [Sequence(0, ll)])
    # Match length extension boundaries (19 = first ext byte, 274 = first
    # 0xFF run) riding an offset-1 RLE chain.
    for ml in (4, 18, 19, 20, 273, 274, 529):
        data = b"z" * (1 + ml)
        seqs = [Sequence(0, 1, ml, 1), Sequence(1 + ml, 0)]
        out[f"rle_{ml}"] = encode_block(data, seqs)
    # Deep RLE chain: the whole block from one seed byte.
    out["zeros"] = _encode_oracle(b"\x00" * MAX_BLOCK)
    # Multi-sequence compressor output (text + structured + random tail).
    out["text"] = _encode_oracle(
        b"the quick brown fox jumps over the lazy dog. " * 400)
    out["structured"] = _encode_oracle(
        bytes(rng.integers(0, 16, 64, np.uint8)) * 40)
    out["lit_tail"] = _encode_oracle(
        bytes(rng.integers(0, 256, 700, np.uint8)) + b"Q" * 900)
    # Final literals-only sequence with a long 0xFF-extended run after
    # matches (the ls_end == n acceptance check, extension on the final).
    data = b"ab" * 40 + bytes(rng.integers(0, 256, 300, np.uint8))
    seqs = [Sequence(0, 2, 78, 2), Sequence(80, 300)]
    out["final_ext"] = encode_block(data, seqs)
    out["one"] = b"\x00"  # empty-literal final token: decodes to b""
    return out


def _lying_corpus() -> dict[str, tuple[bytes, int]]:
    """Malformed streams -> (block, max_out), each targeting one check."""
    fin = b"\x10B"  # final literals-only sequence, 1 byte
    return {
        "zero_offset": (b"\x10A\x00\x00" + fin, MAX_BLOCK),
        "offset_beyond": (b"\x10A\x05\x00" + fin, MAX_BLOCK),
        "missing_final": (b"\x10A\x01\x00", MAX_BLOCK),
        "lit_past_end": (b"\xf0" + b"\xff" * 3, MAX_BLOCK),
        "out_limit_lit": (b"\x40ABCD", 3),
        "out_limit_match": (b"\x1fA\x01\x00\x20" + fin, 10),
        "empty": (b"", MAX_BLOCK),
    }


def _spec_plan(blk: bytes, max_out: int = MAX_BLOCK, use_pallas=False):
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    buf = np.zeros(_CAPS.blk_cap + kops.SPEC_PAD, np.uint8)
    buf[: len(blk)] = np.frombuffer(blk, np.uint8)
    res = kops.plan_speculative(jnp.asarray(buf), jnp.int32(len(blk)),
                                jnp.int32(max_out),
                                max_lit=_CAPS.max_lit,
                                max_match=_CAPS.max_match,
                                out_cap=_CAPS.out_cap,
                                use_pallas=use_pallas)
    return [np.asarray(a) for a in res]


def _assert_plan_matches_oracle(name, blk, use_pallas):
    from repro.kernels import ops as kops

    *cols, status = _spec_plan(blk, use_pallas=use_pallas)
    lit_src, lit_dst, lit_len, match_dst, match_off, match_len = cols
    assert status[kops.SPEC_ERR] == 0, (name, status)
    assert status[kops.SPEC_OVERFLOW] == 0, name
    dp = to_device_plan(plan_block(bytes(blk)), _CAPS, compute_waves=False)
    assert status[kops.SPEC_N_LIT] == dp.n_lit, name
    assert status[kops.SPEC_N_MATCH] == dp.n_match, name
    assert status[kops.SPEC_OUT_SIZE] == dp.out_size, name
    for got, want, col in (
            (lit_src, dp.lit_src, "lit_src"),
            (lit_dst, dp.lit_dst, "lit_dst"),
            (lit_len, dp.lit_len, "lit_len"),
            (match_dst, dp.match_dst, "match_dst"),
            (match_off, dp.match_off, "match_off"),
            (match_len, dp.match_len, "match_len")):
        assert np.array_equal(got, np.asarray(want, np.int32)), (name, col)


# ---------------------------------------------------------------------------
# Plan bit-identity vs the serial oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas"])
def test_speculative_plan_equals_serial_oracle(use_pallas):
    for name, blk in _adversarial_corpus().items():
        _assert_plan_matches_oracle(name, blk, use_pallas)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas"])
def test_speculative_rejects_identically(use_pallas):
    from repro.kernels import ops as kops

    for name, (blk, max_out) in _lying_corpus().items():
        with pytest.raises(LZ4FormatError) as ei:
            plan_block_fast(blk, max_out=max_out)
        *_, status = _spec_plan(blk, max_out=max_out, use_pallas=use_pallas)
        err = int(status[kops.SPEC_ERR])
        assert err != 0, name
        assert _spec_err_message(err) == str(ei.value), name


@pytest.mark.parametrize("name", ["text", "rle_274", "lit_270", "final_ext"])
def test_truncation_sweep_rejects_identically(name):
    """Every truncation of a valid stream: accept with the oracle's exact
    plan or reject with the oracle's exact message — never disagree."""
    from repro.kernels import ops as kops

    blk = _adversarial_corpus()[name]
    step = max(1, len(blk) // 60)
    for cut in list(range(0, len(blk), step)) + [len(blk) - 1]:
        t = blk[:cut]
        try:
            plan_block_fast(t)
            oracle_msg = None
        except LZ4FormatError as e:
            oracle_msg = str(e)
        *_, status = _spec_plan(t)
        err = int(status[kops.SPEC_ERR])
        if oracle_msg is None:
            assert err == 0, (name, cut)
            _assert_plan_matches_oracle(f"{name}[: {cut}]", t, False)
        else:
            assert err != 0, (name, cut, oracle_msg)
            assert _spec_err_message(err) == oracle_msg, (name, cut)


def test_interior_flip_sweep_rejects_identically():
    """Byte rewrites inside the token stream (lying lengths/offsets): the
    speculative parser and the serial parser must agree on accept/reject
    AND on the message; accepted mutants must replan identically."""
    from repro.kernels import ops as kops

    blk = _adversarial_corpus()["text"]
    rng = _rng()
    for _ in range(40):
        m = bytearray(blk)
        pos = int(rng.integers(0, len(blk)))
        m[pos] = int(rng.integers(0, 256))
        m = bytes(m)
        try:
            plan_block_fast(m)
            oracle_msg = None
        except LZ4FormatError as e:
            oracle_msg = str(e)
        *_, status = _spec_plan(m)
        err = int(status[kops.SPEC_ERR])
        if oracle_msg is None:
            if status[kops.SPEC_OVERFLOW]:
                continue  # legal parse that exceeds caps: host fallback
            assert err == 0, pos
            _assert_plan_matches_oracle(f"flip@{pos}", m, False)
        else:
            assert err != 0 and _spec_err_message(err) == oracle_msg, pos


# ---------------------------------------------------------------------------
# Kernel twin identity + the fused plan_decode graph
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["text", "zeros", "rle_529", "lit_525",
                                  "one"])
def test_pallas_kernel_equals_jnp_twin(name):
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.plan_speculative import plan_spec_pallas

    blk = _adversarial_corpus()[name]
    B = _CAPS.blk_cap + 128
    buf = np.zeros(B, np.int32)
    buf[: len(blk)] = np.frombuffer(blk, np.uint8)
    block = jnp.asarray(buf)
    want = ref.plan_fields_ref(block, jnp.int32(len(blk)))
    got = plan_spec_pallas(block, jnp.asarray([len(blk)], jnp.int32))
    for w, g, field in zip(want, got, ("is_start", "lit_start", "lit_len",
                                       "ls_end", "off", "mlen", "flags")):
        assert np.array_equal(np.asarray(w), np.asarray(g)), (name, field)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas"])
def test_fused_plan_decode_payload_and_crc(use_pallas):
    import jax.numpy as jnp

    from repro.core import block_crc
    from repro.core.decode_plan import MAX_RESOLVE_ROUNDS
    from repro.kernels import ops as kops
    from repro.kernels.ops import plan_decode

    corpus = _adversarial_corpus()
    for name in ("text", "lit_tail", "rle_274", "final_ext"):
        blk = corpus[name]
        data = _decode_oracle(blk)
        buf = np.zeros(_CAPS.blk_cap + kops.SPEC_PAD, np.uint8)
        buf[: len(blk)] = np.frombuffer(blk, np.uint8)
        out, status, crc = plan_decode(
            jnp.asarray(buf), jnp.int32(len(blk)), jnp.int32(MAX_BLOCK),
            out_cap=_CAPS.out_cap, max_lit=_CAPS.max_lit,
            max_match=_CAPS.max_match, rounds=MAX_RESOLVE_ROUNDS,
            use_pallas=use_pallas)
        status = np.asarray(status)
        assert status[kops.SPEC_ERR] == 0, name
        size = int(status[kops.SPEC_OUT_SIZE])
        got = np.asarray(out)[:size].tobytes()
        assert got == data, name
        assert int(crc) == block_crc(data), name


def _decode_oracle(blk: bytes) -> bytes:
    from repro.core import decode_block_bytewise

    return decode_block_bytewise(blk)


# ---------------------------------------------------------------------------
# Engine path: plan_on_device
# ---------------------------------------------------------------------------

def _frame_corpus() -> dict[str, bytes]:
    rng = _rng()
    return {
        "empty": b"",
        "tiny": b"xyz",
        "multi_text": b"spam and eggs and ham, " * 12000,
        "zeros_multi": b"\x00" * (2 * MAX_BLOCK + 17),
        "raw_multi": rng.integers(0, 256, MAX_BLOCK + 5000,
                                  np.uint8).tobytes(),
        "mixed": ((b"ab" * MAX_BLOCK)[:MAX_BLOCK - 7]
                  + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()
                  + b"pattern-" * 4000),
    }


@pytest.fixture(scope="module")
def engine():
    return LZ4Engine(micro_batch=4)


@pytest.fixture(scope="module")
def spec_engine():
    return LZ4DecodeEngine(executor="device", plan_on_device=True,
                           micro_batch=4)


def test_plan_on_device_requires_device_executor():
    with pytest.raises(ValueError, match="plan_on_device"):
        LZ4DecodeEngine(plan_on_device=True)
    with pytest.raises(ValueError, match="plan_on_device"):
        LZ4DecodeEngine(executor="thread", plan_on_device=True)


def test_specplan_engine_bit_identical(engine, spec_engine):
    for name, data in _frame_corpus().items():
        frame = engine.compress(data)
        got = spec_engine.decode(frame)
        assert got == data, name
        assert got == decode_frame_serial(frame), name


def test_specplan_engine_pallas_variant(engine):
    de = LZ4DecodeEngine(executor="device", plan_on_device=True,
                         use_pallas=True, micro_batch=2)
    data = b"pallas speculative parity " * 9000
    frame = engine.compress(data)
    assert de.decode(frame) == data
    assert de.stats.device_blocks == de.stats.blocks


def test_specplan_no_host_planner_calls(engine, monkeypatch):
    """The clean device path must never touch the host parser: planning,
    execution, and CRC verification all live in the jit graph."""
    import repro.core.decode_engine as dem

    data = b"no host planning " * 15000
    frame = engine.compress(data)

    def _boom(*a, **k):
        raise AssertionError("host planner called on the speculative path")

    monkeypatch.setattr(dem, "plan_block_fast", _boom)
    de = LZ4DecodeEngine(executor="device", plan_on_device=True)
    assert de.decode(frame) == data
    assert de.stats.fallback_blocks == 0
    assert de.stats.device_blocks == de.stats.blocks


def test_specplan_to_device_zero_host_bytes(engine, spec_engine):
    import jax

    data = _frame_corpus()["mixed"]
    frame = engine.compress(data)
    dev = spec_engine.decode_to_device(frame)
    assert isinstance(dev, jax.Array)
    assert np.asarray(dev).tobytes() == data
    # host_bytes == 0 now INCLUDES planning: no token stream walk on host.
    assert spec_engine.stats.host_bytes == 0
    dev2 = spec_engine.decode_to_device(frame, verify=False)
    assert spec_engine.stats.host_bytes == 0
    assert np.asarray(dev2).tobytes() == data


def test_specplan_read_range_device_zero_host_bytes(engine, spec_engine):
    from repro.core import FrameReader

    data = _frame_corpus()["multi_text"]
    frame = engine.compress(data)
    reader = FrameReader(frame, engine=spec_engine)
    for start, length in [(0, 1), (MAX_BLOCK - 3, 7), (70000, 9000)]:
        got = np.asarray(reader.read_range_device(start, length)).tobytes()
        assert got == data[start: start + length], (start, length)
    assert spec_engine.stats.host_bytes == 0


def test_specplan_offloaded_reader_to_device():
    from repro.serving.engine import OffloadedCacheReader, offload_cache

    import jax.numpy as jnp

    rng = _rng()
    cache = {"k": jnp.asarray((rng.integers(0, 3, (2, 128, 64)) * 0.5)
                              .astype(np.float32))}
    blob, _ = offload_cache(cache)
    de = LZ4DecodeEngine(executor="device", plan_on_device=True)
    rdr = OffloadedCacheReader(blob, decode_engine=de, to_device=True)
    restored = rdr.restore()
    assert (np.asarray(restored["k"]) == np.asarray(cache["k"])).all()
    assert de.stats.host_bytes == 0


def test_specplan_corruption_parity(engine, spec_engine):
    """Flips through the speculative engine behave exactly like the serial
    oracle: reject (any FrameFormatError) or decode the SAME bytes."""
    data = b"the quick brown fox " * 9000
    frame = engine.compress(data)
    n = len(frame)
    positions = list(range(min(48, n))) + \
        list(range(48, n, max(1, n // 40))) + [n - 1]
    for pos in positions:
        mutant = bytearray(frame)
        mutant[pos] ^= 0x40
        mutant = bytes(mutant)
        try:
            oracle = decode_frame_serial(mutant)
        except FrameFormatError:
            oracle = None
        try:
            got = spec_engine.decode(mutant)
        except FrameFormatError:
            assert oracle is None, f"spec rejected, oracle accepted @ {pos}"
            continue
        assert oracle is not None, f"spec accepted, oracle rejected @ {pos}"
        assert got == oracle, pos


def test_specplan_error_message_parity(engine, spec_engine):
    """A parse-breaking payload flip must surface the oracle's exact
    per-block message (e.g. 'block 0: zero offset') through the engine."""
    from repro.core import block_crc, encode_frame

    blk, _ = _lying_corpus()["zero_offset"]
    frame = encode_frame([blk], [3], [False], checksums=[block_crc(b"AB?")])
    with pytest.raises(FrameFormatError) as serial_err:
        decode_frame_serial(frame)
    with pytest.raises(FrameFormatError) as spec_err:
        spec_engine.decode(frame)
    assert str(spec_err.value) == str(serial_err.value)
    assert "zero offset" in str(spec_err.value)


# ---------------------------------------------------------------------------
# Sharded fabric: the same in-graph path under shard_map (subprocess leg)
# ---------------------------------------------------------------------------

_MESH_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.core.engine import LZ4Engine
    from repro.core.decode_engine import LZ4DecodeEngine
    from repro.distributed.sharding import make_mesh_compat

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(7)
    data = (b"sharded speculative planning " * 5000
            + rng.integers(0, 256, 30000, np.uint8).tobytes())
    frame = LZ4Engine(micro_batch=4, shards=3).compress(data)
    results = {}
    for up in (False, True):
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        dec = LZ4DecodeEngine(mesh=mesh, executor="device",
                              plan_on_device=True, micro_batch=2,
                              use_pallas=up)
        assert dec.decode(frame) == data, up
        st = dec.stats
        assert st.fallback_blocks == 0, st
        assert st.device_blocks == st.blocks - st.raw_blocks, st
        results["pallas" if up else "jnp"] = {
            "dispatches": st.dispatches,
            "device_blocks": st.device_blocks,
        }
    print("RESULT:" + json.dumps({"ok": True, "meshes": results}))
""")


def test_subprocess_mesh_specplan():
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SUBPROC],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src:.", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    result = json.loads(line[len("RESULT:"):])
    assert result["ok"]
    for leg in ("jnp", "pallas"):
        assert result["meshes"][leg]["device_blocks"] > 0
