"""Telemetry layer: span tracing, metrics registry, exporters, no-op cost.

Covers the `repro.obs` contract end to end:

  * span nesting (depth/parent reconstruction) and thread-safety under a
    ThreadPoolExecutor;
  * Chrome trace-event export shape (Perfetto-loadable: "X" events with
    numeric ts/dur in microseconds, M metadata rows);
  * histogram quantile estimates vs `np.percentile` (error bounded by one
    bucket width);
  * Prometheus text exposition golden test (cumulative buckets, +Inf,
    sanitized names);
  * the disabled path: no events recorded, frames byte-identical with
    telemetry on vs off, and a <2% overhead guard on a compress microloop;
  * EngineStats/DecodeStats lifecycle: per-call `stats` vs lifetime
    `totals`, `as_dict()` round-trips;
  * `tools/trace_report.py` round-trip over a real exported bundle.
"""
from __future__ import annotations

import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    exponential_buckets,
    linear_buckets,
)
from repro.obs.trace import Tracer

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_report  # noqa: E402


@pytest.fixture
def enabled_obs():
    """Enable telemetry for one test, restoring prior state after."""
    was = obs.is_enabled()
    obs.configure(enabled=True)
    obs.reset()
    yield obs
    obs.reset()
    obs.configure(enabled=was)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_parent():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("mid"):
            with tr.span("inner"):
                pass
        with tr.span("mid2"):
            pass
    done = {e["name"]: e for e in tr.finished()}
    assert done["outer"]["depth"] == 0 and done["outer"]["parent"] is None
    assert done["mid"]["depth"] == 1 and done["mid"]["parent"] == "outer"
    assert done["inner"]["depth"] == 2 and done["inner"]["parent"] == "mid"
    assert done["mid2"]["depth"] == 1 and done["mid2"]["parent"] == "outer"
    # Children close before parents, and fit inside them.
    assert done["inner"]["dur_ns"] <= done["outer"]["dur_ns"]


def test_span_records_args_and_duration():
    tr = Tracer()
    with tr.span("work", rows=7, impl="sort"):
        time.sleep(0.002)
    (ev,) = tr.finished()
    assert ev["args"] == {"rows": 7, "impl": "sort"}
    assert ev["dur_ns"] >= 2_000_000  # slept 2 ms


def test_tracer_thread_safety():
    tr = Tracer()

    def work(i):
        for _ in range(200):
            with tr.span("outer", worker=i):
                with tr.span("inner"):
                    pass

    with ThreadPoolExecutor(max_workers=8) as ex:
        list(ex.map(work, range(8)))
    events = tr.finished()
    assert len(events) == 8 * 200 * 2
    # Nesting is per-thread: every inner has parent outer, never cross-thread.
    assert all(e["parent"] == "outer" for e in events if e["name"] == "inner")
    # JSONL export carries every event, one object per line.
    lines = [ln for ln in tr.jsonl_events().splitlines() if ln]
    assert len(lines) == 8 * 200 * 2
    assert json.loads(lines[0])["name"] in ("outer", "inner")


def test_chrome_trace_shape_perfetto_loadable():
    tr = Tracer()
    with tr.span("a", k=1):
        with tr.span("b"):
            pass
    doc = tr.chrome_trace()
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == 2 and ms, "want complete events + metadata rows"
    for e in xs:
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["dur"] >= 0 and {"name", "pid", "tid", "cat"} <= e.keys()
    a = next(e for e in xs if e["name"] == "a")
    b = next(e for e in xs if e["name"] == "b")
    # b nests inside a on the same track (microsecond units).
    assert a["ts"] <= b["ts"] and b["ts"] + b["dur"] <= a["ts"] + a["dur"] + 1
    json.dumps(doc)  # must be serializable as-is


def test_tracer_drop_cap():
    tr = Tracer(max_events=10)
    for i in range(25):
        with tr.span("s"):
            pass
    assert len(tr.finished()) == 10
    assert tr.dropped == 15
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 15


def test_tracer_reset():
    tr = Tracer()
    with tr.span("x"):
        pass
    tr.reset()
    assert tr.finished() == [] and tr.jsonl_events() == ""
    with tr.span("y"):  # usable after reset
        pass
    assert len(tr.finished()) == 1


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests")
    c.inc()
    c.inc(4)
    g = reg.gauge("inflight", "in flight")
    g.set(3)
    g.inc()
    g.dec(2)
    snap = reg.snapshot()
    assert snap["counters"]["reqs"] == 5
    assert snap["gauges"]["inflight"] == 2
    with pytest.raises(TypeError):
        reg.gauge("reqs", "wrong type for existing name")


def test_bucket_builders():
    lin = linear_buckets(0.0, 1.0, 5)
    assert lin == (0.0, 1.0, 2.0, 3.0, 4.0)
    exp = exponential_buckets(1.0, 2.0, 4)
    assert exp == (1.0, 2.0, 4.0, 8.0)
    assert all(a < b for a, b in zip(exp, exp[1:]))
    with pytest.raises(ValueError):
        exponential_buckets(0.0, 2.0, 4)
    with pytest.raises(ValueError):
        linear_buckets(0.0, -1.0, 4)


def test_histogram_quantiles_vs_numpy():
    rng = np.random.default_rng(42)
    samples = rng.lognormal(mean=-7.0, sigma=1.2, size=5000)  # latency-ish
    buckets = exponential_buckets(1e-6, 1.3, 60)
    h = Histogram("lat", buckets, help="latency")
    for s in samples:
        h.observe(float(s))
    for q in (0.50, 0.90, 0.99):
        est = h.quantile(q)
        ref = float(np.percentile(samples, q * 100))
        # Interpolated estimate is off by at most one bucket width at ref.
        idx = int(np.searchsorted(buckets, ref))
        width = (buckets[min(idx + 1, len(buckets) - 1)]
                 - buckets[max(idx - 1, 0)])
        assert abs(est - ref) <= width, (q, est, ref, width)


def test_histogram_snapshot_fields():
    h = Histogram("h", [1.0, 2.0, 4.0])
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4 and s["sum"] == pytest.approx(105.0)
    assert s["min"] == 0.5 and s["max"] == 100.0
    assert s["buckets"][-1][0] == "+Inf" and s["buckets"][-1][1] == 1
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)


def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("engine.blocks", "blocks compressed").inc(3)
    reg.gauge("engine.inflight_batches", "in flight").set(1)
    h = reg.histogram("engine.wait_seconds", help="wait", buckets=[0.1, 1.0])
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    expected = "\n".join([
        "# HELP engine_blocks blocks compressed",
        "# TYPE engine_blocks counter",
        "engine_blocks 3",
        "# HELP engine_inflight_batches in flight",
        "# TYPE engine_inflight_batches gauge",
        "engine_inflight_batches 1",
        "# HELP engine_wait_seconds wait",
        "# TYPE engine_wait_seconds histogram",
        'engine_wait_seconds_bucket{le="0.1"} 1',
        'engine_wait_seconds_bucket{le="1.0"} 2',
        'engine_wait_seconds_bucket{le="+Inf"} 3',
        f"engine_wait_seconds_sum {0.05 + 0.5 + 5.0}",
        "engine_wait_seconds_count 3",
        "",
    ])
    assert text == expected


def test_registry_reset():
    reg = MetricsRegistry()
    reg.counter("c", "").inc(9)
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# gating / facade
# ---------------------------------------------------------------------------

def test_disabled_records_nothing():
    obs.configure(enabled=False)
    obs.reset()
    with obs.span("ghost", x=1):
        obs.counter("ghost.count", "").inc()
        obs.histogram("ghost.h").observe(1.0)
    assert obs.tracer().finished() == []
    snap = obs.snapshot()
    assert snap["enabled"] is False
    assert snap["metrics"] == {"counters": {}, "gauges": {}, "histograms": {}}


def test_enabled_facade_and_dump(enabled_obs, tmp_path):
    with obs.span("stage.a", rows=2):
        obs.counter("n", "things").inc(2)
    paths = obs.dump_artifacts(str(tmp_path / "bundle"))
    assert set(paths) == {"trace", "events", "metrics", "prometheus"}
    with open(paths["trace"]) as f:
        doc = json.load(f)
    assert any(e.get("name") == "stage.a" for e in doc["traceEvents"])
    with open(paths["metrics"]) as f:
        m = json.load(f)
    assert m["schema_version"] == obs.ARTIFACT_SCHEMA_VERSION
    assert m["metrics"]["counters"]["n"] == 2
    with open(paths["events"]) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    assert lines and lines[0]["name"] == "stage.a"


def test_span_factory_gating(enabled_obs):
    live = obs.span_factory(True)
    noop = obs.span_factory(False)
    with live("real"):
        pass
    with noop("fake"):
        pass
    names = {e["name"] for e in obs.tracer().finished()}
    assert names == {"real"}


# ---------------------------------------------------------------------------
# engine integration: spans, stats lifecycle, identical output, overhead
# ---------------------------------------------------------------------------

def _data(n_blocks=2):
    from repro.core import corpus_blocks
    from repro.core.lz4_types import MAX_BLOCK

    full = [b for b in corpus_blocks() if len(b) == MAX_BLOCK]
    return b"".join((full * n_blocks)[:n_blocks])


def test_engine_spans_and_counters(enabled_obs):
    from repro.core import LZ4DecodeEngine, LZ4Engine

    data = _data()
    eng = LZ4Engine(micro_batch=8, telemetry=True)
    frame = eng.compress(data)
    dec = LZ4DecodeEngine(telemetry=True)
    assert dec.decode(frame) == data

    names = {e["name"] for e in obs.tracer().finished()}
    assert {"compress.total", "compress.dispatch", "compress.wait",
            "compress.drain", "compress.frame"} <= names
    assert {"decode.total", "decode.execute"} <= names
    snap = obs.snapshot()["metrics"]
    assert snap["counters"]["engine.calls"] == 1
    assert snap["counters"]["engine.bytes_in"] == len(data)
    assert snap["counters"]["decode.bytes_out"] == len(data)
    assert snap["histograms"]["engine.block_ratio"]["count"] >= 1


def test_stats_per_call_vs_totals():
    from repro.core import LZ4DecodeEngine, LZ4Engine

    data = _data()
    eng = LZ4Engine(micro_batch=8)
    f1 = eng.compress(data)
    per_call = eng.stats.bytes_in
    eng.compress(data)
    assert eng.stats.bytes_in == per_call, "stats must be per-call"
    assert eng.totals.bytes_in == 2 * per_call, "totals must accumulate"
    assert eng.totals.calls == 2

    dec = LZ4DecodeEngine()
    dec.decode(f1)
    dec.decode(f1)
    assert dec.stats.calls == 1 and dec.totals.calls == 2
    assert dec.totals.bytes_out == 2 * len(data)

    d = eng.totals.as_dict()
    assert d["calls"] == 2 and d["bytes_in"] == 2 * per_call
    dd = dec.totals.as_dict()
    assert dd["calls"] == 2 and isinstance(dd, dict)


def test_frames_identical_telemetry_on_off(enabled_obs):
    from repro.core import LZ4Engine

    data = _data()
    frame_on = LZ4Engine(micro_batch=8, telemetry=True).compress(data)
    frame_off = LZ4Engine(micro_batch=8, telemetry=False).compress(data)
    assert frame_on == frame_off, "telemetry must not change frame bytes"


def test_noop_overhead_under_budget():
    """Disabled telemetry must cost <2% on the compress microloop."""
    from repro.core import LZ4Engine

    obs.configure(enabled=False)
    data = _data(1)
    eng = LZ4Engine(micro_batch=8, telemetry=False)
    eng.compress(data)  # warmup/jit

    def loop(n=6):
        t0 = time.perf_counter()
        for _ in range(n):
            eng.compress(data)
        return time.perf_counter() - t0

    loop(2)  # settle caches
    per_call = min(loop() for _ in range(3)) / 6
    # The disabled hot path is: one flag test per call site plus a shared
    # no-op context manager.  Measure that microcost directly and scale it
    # by the number of span entries a compress call actually makes — it
    # must land under 2% of the measured per-call time.
    sp = obs.span_factory(False)
    t0 = time.perf_counter()
    for _ in range(100_000):
        with sp("x", rows=1):
            pass
    per_span = (time.perf_counter() - t0) / 100_000
    spans_per_call = 4 + 3 * 8  # total/frame/pad + dispatch/wait/drain per mb
    assert per_span * spans_per_call < 0.02 * per_call, (
        per_span, spans_per_call, per_call)


# ---------------------------------------------------------------------------
# trace_report round-trip
# ---------------------------------------------------------------------------

def test_trace_report_roundtrip(enabled_obs, tmp_path, capsys):
    from repro.core import LZ4DecodeEngine, LZ4Engine

    data = _data()
    frame = LZ4Engine(micro_batch=8, telemetry=True).compress(data)
    LZ4DecodeEngine(telemetry=True).decode(frame)
    bundle = str(tmp_path / "bundle")
    obs.dump_artifacts(bundle)

    assert trace_report.main([bundle, "--check"]) == 0
    out = capsys.readouterr().out
    assert "schema-valid" in out

    assert trace_report.main([bundle]) == 0
    table = capsys.readouterr().out
    for stage in ("compress.dispatch", "compress.wait", "compress.drain",
                  "decode.execute", "compress.total"):
        assert stage in table
    assert "engine.calls" in table  # counters section

    assert trace_report.main([bundle, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["breakdown"]["stages"]["compress.total"]["count"] == 1
    assert doc["breakdown"]["wall_ms"] > 0


def test_trace_report_check_rejects_malformed(tmp_path, capsys):
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "trace.json").write_text(json.dumps({"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1}  # missing tid/ts/dur
    ]}))
    (bad / "metrics.json").write_text(json.dumps({"metrics": {}}))
    assert trace_report.main([str(bad), "--check"]) == 1
    err = capsys.readouterr().err
    assert "schema problem" in err


def test_trace_report_empty_trace_fails_check(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    (empty / "trace.json").write_text(json.dumps({"traceEvents": []}))
    (empty / "metrics.json").write_text(json.dumps(
        {"schema_version": 1,
         "metrics": {"counters": {}, "gauges": {}, "histograms": {}}}))
    assert trace_report.main([str(empty), "--check"]) == 1


def test_totals_exact_under_concurrent_engine_use():
    """`engine.totals` must not lose updates when one engine instance is
    shared across threads (the serving-offload pattern: many requests, one
    `default_engine()`).  Each call carries its own per-call stats object;
    the engine folds them into `totals` under a lock — so the lifetime
    counters are EXACT, not approximately right."""
    from repro.core import LZ4DecodeEngine, LZ4Engine

    data = _data()
    n_threads, calls_per_thread = 8, 6
    eng = LZ4Engine(micro_batch=8)
    frame = eng.compress(data)  # warm the jit cache outside the timed region
    base_calls = eng.totals.calls
    base_bytes = eng.totals.bytes_in

    with ThreadPoolExecutor(n_threads) as pool:
        frames = list(pool.map(
            lambda _: eng.compress(data), range(n_threads * calls_per_thread)))
    assert all(f == frame for f in frames)  # concurrency never changes bytes
    n = n_threads * calls_per_thread
    assert eng.totals.calls == base_calls + n
    assert eng.totals.bytes_in == base_bytes + n * len(data)

    dec = LZ4DecodeEngine()
    dec.decode(frame)
    dbase = dec.totals.calls
    with ThreadPoolExecutor(n_threads) as pool:
        outs = list(pool.map(
            lambda _: dec.decode(frame), range(n_threads * calls_per_thread)))
    assert all(o == data for o in outs)
    assert dec.totals.calls == dbase + n
    assert dec.totals.bytes_out == (dbase + n) * len(data)
