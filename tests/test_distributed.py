"""Distribution tests: sharding rules, cache specs, and a subprocess dry-run
smoke on fake devices (the main pytest process keeps its single device)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, cells, LONG_CONTEXT_ARCHS
from repro.distributed import sharding as sh


class TestSpecRules:
    def test_param_specs_cover_tree(self):
        from repro.models import lm

        cfg = get_config("internlm2-1.8b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        mesh = sh.single_device_mesh()
        specs = sh.param_specs(params_s, fsdp=True, mesh=mesh)
        n_leaves = len(jax.tree.leaves(params_s))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves

    def test_tp_on_heads_and_ff(self):
        from repro.models import lm

        cfg = get_config("qwen3-1.7b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        mesh = sh.single_device_mesh()
        specs = sh.param_specs(params_s, fsdp=True, mesh=mesh)
        layer = specs["segments"][0]["layers"]["0"]
        assert layer["attn"]["wq"]["w"] == P(None, "data", "model")
        assert layer["attn"]["wo"]["w"] == P(None, "model", "data")
        assert layer["mlp"]["w_gate"]["w"] == P(None, "data", "model")
        assert specs["embed"] == P("model", "data")

    def test_moe_expert_specs(self):
        from repro.models import lm

        cfg = get_config("mixtral-8x7b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        specs = sh.param_specs(params_s, fsdp=True, mesh=sh.single_device_mesh())
        layer = specs["segments"][0]["layers"]["0"]
        assert layer["moe"]["w1"]["w"] == P(None, None, "data", "model")
        assert layer["moe"]["w2"]["w"] == P(None, None, "model", "data")

    def test_sanitize_drops_uneven(self):
        import types

        from repro.launch.steps import sanitize_spec

        mesh = types.SimpleNamespace(shape={"data": 16, "model": 16, "pod": 2})
        # whisper vocab 51865 is odd -> model axis must be dropped
        assert sanitize_spec(P("model", None), (51865, 768), mesh) == P(None, None)
        assert sanitize_spec(P("model", None), (92544, 768), mesh) == P("model", None)
        # tuple axes: 256-way sharding of 524288 divides, 1500 does not
        assert sanitize_spec(P(None, ("data", "model")), (1, 524288), mesh) == \
            P(None, ("data", "model"))
        assert sanitize_spec(P(None, ("data", "model")), (1, 1500), mesh) == P(None, None)

    def test_cell_enumeration(self):
        cs = cells()
        assert len(cs) == 35  # 30 + 5 long-context
        skipped = [c for c in cells(include_skipped=True) if c not in cs]
        assert all(s[1] == "long_500k" and s[0] not in LONG_CONTEXT_ARCHS for s in skipped)
        assert len(cells(include_skipped=True)) == 40


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    from repro.configs.base import get_config, SHAPES, ShapeConfig
    from repro.distributed.sharding import use_mesh
    from repro.launch import steps as steps_mod
    from repro.launch.dryrun import parse_collectives, _lower_cell
    import dataclasses

    from repro.distributed.sharding import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 4), ("pod", "data", "model"))
    cfg = dataclasses.replace(
        get_config("{arch}").reduced(), fsdp=True,
        d_model=128, n_heads=8, head_dim=16, d_ff=256 if get_config("{arch}").d_ff else 0,
        vocab_size=1024,
    )
    shape = ShapeConfig("t", seq_len=64, global_batch=8, mode="{mode}")
    with use_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        coll = parse_collectives(compiled.as_text())
        print("RESULT:" + json.dumps({{
            "ok": True,
            "n_coll": sum(v["count"] for v in coll.values()),
            "ops": sorted(coll.keys()),
        }}))
""")


@pytest.mark.parametrize("arch,mode", [
    ("internlm2-1.8b", "train"),
    ("mixtral-8x7b", "train"),
    ("gemma2-9b", "decode"),
    ("xlstm-125m", "prefill"),
])
def test_subprocess_multipod_smoke(arch, mode):
    """Reduced configs compile against a (pod,data,model) mesh with real
    collectives — proves the sharding rules are coherent end to end."""
    code = _SUBPROC.format(arch=arch, mode=mode)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    result = json.loads(line[len("RESULT:"):])
    assert result["ok"]
    if mode == "train":
        assert result["n_coll"] > 0  # DP gradient reduction must exist
