"""Distribution tests: sharding rules, cache specs, and a subprocess dry-run
smoke on fake devices (the main pytest process keeps its single device)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, cells, LONG_CONTEXT_ARCHS
from repro.distributed import sharding as sh


class TestSpecRules:
    def test_param_specs_cover_tree(self):
        from repro.models import lm

        cfg = get_config("internlm2-1.8b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        mesh = sh.single_device_mesh()
        specs = sh.param_specs(params_s, fsdp=True, mesh=mesh)
        n_leaves = len(jax.tree.leaves(params_s))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves

    def test_tp_on_heads_and_ff(self):
        from repro.models import lm

        cfg = get_config("qwen3-1.7b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        mesh = sh.single_device_mesh()
        specs = sh.param_specs(params_s, fsdp=True, mesh=mesh)
        layer = specs["segments"][0]["layers"]["0"]
        assert layer["attn"]["wq"]["w"] == P(None, "data", "model")
        assert layer["attn"]["wo"]["w"] == P(None, "model", "data")
        assert layer["mlp"]["w_gate"]["w"] == P(None, "data", "model")
        assert specs["embed"] == P("model", "data")

    def test_moe_expert_specs(self):
        from repro.models import lm

        cfg = get_config("mixtral-8x7b")
        params_s = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        specs = sh.param_specs(params_s, fsdp=True, mesh=sh.single_device_mesh())
        layer = specs["segments"][0]["layers"]["0"]
        assert layer["moe"]["w1"]["w"] == P(None, None, "data", "model")
        assert layer["moe"]["w2"]["w"] == P(None, None, "model", "data")

    def test_sanitize_drops_uneven(self):
        import types

        from repro.launch.steps import sanitize_spec

        mesh = types.SimpleNamespace(shape={"data": 16, "model": 16, "pod": 2})
        # whisper vocab 51865 is odd -> model axis must be dropped
        assert sanitize_spec(P("model", None), (51865, 768), mesh) == P(None, None)
        assert sanitize_spec(P("model", None), (92544, 768), mesh) == P("model", None)
        # tuple axes: 256-way sharding of 524288 divides, 1500 does not
        assert sanitize_spec(P(None, ("data", "model")), (1, 524288), mesh) == \
            P(None, ("data", "model"))
        assert sanitize_spec(P(None, ("data", "model")), (1, 1500), mesh) == P(None, None)

    def test_cell_enumeration(self):
        cs = cells()
        assert len(cs) == 35  # 30 + 5 long-context
        skipped = [c for c in cells(include_skipped=True) if c not in cs]
        assert all(s[1] == "long_500k" and s[0] not in LONG_CONTEXT_ARCHS for s in skipped)
        assert len(cells(include_skipped=True)) == 40


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    from repro.configs.base import get_config, SHAPES, ShapeConfig
    from repro.distributed.sharding import use_mesh
    from repro.launch import steps as steps_mod
    from repro.launch.dryrun import parse_collectives, _lower_cell
    import dataclasses

    from repro.distributed.sharding import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 4), ("pod", "data", "model"))
    cfg = dataclasses.replace(
        get_config("{arch}").reduced(), fsdp=True,
        d_model=128, n_heads=8, head_dim=16, d_ff=256 if get_config("{arch}").d_ff else 0,
        vocab_size=1024,
    )
    shape = ShapeConfig("t", seq_len=64, global_batch=8, mode="{mode}")
    with use_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        coll = parse_collectives(compiled.as_text())
        print("RESULT:" + json.dumps({{
            "ok": True,
            "n_coll": sum(v["count"] for v in coll.values()),
            "ops": sorted(coll.keys()),
        }}))
""")


@pytest.mark.parametrize("arch,mode", [
    ("internlm2-1.8b", "train"),
    ("mixtral-8x7b", "train"),
    ("gemma2-9b", "decode"),
    ("xlstm-125m", "prefill"),
])
def test_subprocess_multipod_smoke(arch, mode):
    """Reduced configs compile against a (pod,data,model) mesh with real
    collectives — proves the sharding rules are coherent end to end."""
    code = _SUBPROC.format(arch=arch, mode=mode)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    result = json.loads(line[len("RESULT:"):])
    assert result["ok"]
    if mode == "train":
        assert result["n_coll"] > 0  # DP gradient reduction must exist


# ---------------------------------------------------------------------------
# Sharded compression fabric (distributed/fabric.py, frame v4).
# ---------------------------------------------------------------------------

from repro.core.decode_engine import FrameReader, LZ4DecodeEngine  # noqa: E402
from repro.core.engine import LZ4Engine  # noqa: E402
from repro.core.frame import VERSION_V4, decode_frame_serial, frame_info  # noqa: E402
from repro.core.lz4_types import MAX_BLOCK  # noqa: E402
from repro.distributed import fabric  # noqa: E402


def _fabric_corpus(n_blocks: int, seed: int = 0) -> bytes:
    """Adversarial mixed corpus spanning exactly ``n_blocks`` 64 KB blocks:
    RLE runs, structured text, and an incompressible tail."""
    import random

    rng = random.Random(seed)
    total = (n_blocks - 1) * MAX_BLOCK + MAX_BLOCK // 3
    parts, n = [], 0
    while n < total:
        kind = rng.randrange(3)
        if kind == 0:
            piece = bytes([rng.randrange(256)]) * rng.randrange(100, 9000)
        elif kind == 1:
            piece = (b"the quick brown fox %d " % rng.randrange(1000)) * \
                rng.randrange(10, 300)
        else:
            piece = bytes(rng.randrange(256) for _ in range(
                rng.randrange(500, 8000)))
        parts.append(piece)
        n += len(piece)
    return b"".join(parts)[:total]


class TestPartitionBlocks:
    def test_balanced_and_contiguous(self):
        sls = fabric.partition_blocks(10, 4)
        assert [s.count for s in sls] == [3, 3, 2, 2]
        assert sls[0].start == 0 and sls[-1].stop == 10
        for a, b in zip(sls, sls[1:]):
            assert a.stop == b.start

    def test_even_split(self):
        assert [s.count for s in fabric.partition_blocks(8, 4)] == [2, 2, 2, 2]

    def test_more_shards_than_blocks(self):
        sls = fabric.partition_blocks(2, 5)
        assert [s.count for s in sls] == [1, 1, 0, 0, 0]

    def test_zero_blocks(self):
        assert all(s.count == 0 for s in fabric.partition_blocks(0, 3))

    def test_invalid_shards(self):
        with pytest.raises(ValueError):
            fabric.partition_blocks(4, 0)


class TestHostPathFabric:
    """Host-partition path: runs on a single device, writes the same v4
    container the mesh path does (and IS the mesh path's oracle)."""

    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_round_trip_v4(self, shards):
        data = _fabric_corpus(5, seed=shards)
        eng = LZ4Engine(shards=shards)
        frame = eng.compress(data)
        info = frame_info(frame)
        assert info["version"] == VERSION_V4
        assert info["shard_count"] == shards
        assert decode_frame_serial(frame) == data
        assert LZ4DecodeEngine().decode(frame) == data
        assert eng.stats.shards == shards

    @pytest.mark.parametrize("n_blocks,shards", [(5, 2), (7, 4), (3, 8)])
    def test_uneven_blocks(self, n_blocks, shards):
        """blocks % shards != 0: trailing shards own fewer (or zero) blocks."""
        data = _fabric_corpus(n_blocks, seed=n_blocks)
        frame = LZ4Engine(shards=shards).compress(data)
        info = frame_info(frame)
        assert info["block_count"] == n_blocks
        counts = [0] * shards
        for b in info["blocks"]:
            counts[b["shard"]] += 1
        assert counts == [s.count for s in
                          fabric.partition_blocks(n_blocks, shards)]
        assert decode_frame_serial(frame) == data

    def test_per_shard_byte_identity(self):
        """The core invariant: each shard's blocks are byte-identical to a
        single-device engine run on that shard's slice of the input."""
        data = _fabric_corpus(6, seed=42)
        shards = 3
        frame = LZ4Engine(shards=shards).compress(data)
        single = LZ4Engine()
        chunks = [data[i: i + MAX_BLOCK]
                  for i in range(0, len(data), MAX_BLOCK)]
        for sl in fabric.partition_blocks(len(chunks), shards):
            piece = b"".join(chunks[sl.start: sl.stop])
            assert fabric.shard_subframe(frame, sl.shard) == \
                single.compress(piece)

    def test_read_range_across_shard_boundary(self):
        data = _fabric_corpus(6, seed=7)
        frame = LZ4Engine(shards=3).compress(data)
        r = FrameReader(frame)
        # shard boundary after block 2 (6 blocks / 3 shards = 2 each)
        b = 2 * MAX_BLOCK
        for start, length in [(b - 100, 200), (0, len(data)),
                              (b - 1, 2), (4 * MAX_BLOCK - 10, 20)]:
            assert r.read_range(start, length) == data[start: start + length]

    def test_empty_input(self):
        frame = LZ4Engine(shards=2).compress(b"")
        assert frame_info(frame)["version"] == VERSION_V4
        assert decode_frame_serial(frame) == b""

    def test_compress_to_blocks_matches_unsharded(self):
        data = _fabric_corpus(5, seed=9)
        assert LZ4Engine(shards=4).compress_to_blocks(data) == \
            LZ4Engine().compress_to_blocks(data)

    def test_unsharded_stays_v3(self):
        assert frame_info(LZ4Engine().compress(b"x" * 1000))["version"] == 3


class TestFabricConfigValidation:
    def test_shard_axes_without_mesh(self):
        with pytest.raises(ValueError, match="requires mesh"):
            LZ4Engine(shard_axes=("data",))
        with pytest.raises(ValueError, match="requires mesh"):
            LZ4DecodeEngine(shard_axes=("data",))

    def test_bad_shards(self):
        with pytest.raises(ValueError, match="shards"):
            LZ4Engine(shards=0)

    def test_unknown_axis(self):
        mesh = sh.single_device_mesh()
        with pytest.raises(ValueError, match="not in mesh"):
            LZ4Engine(mesh=mesh, shard_axes=("nope",))
        with pytest.raises(ValueError, match="not in mesh"):
            LZ4DecodeEngine(mesh=mesh, shard_axes=("nope",))

    def test_mesh_shard_count_matches_mesh(self):
        mesh = sh.single_device_mesh()
        eng = LZ4Engine(mesh=mesh)
        assert eng.shards == 1  # 1x1x1 mesh
        with pytest.raises(ValueError, match="!= mesh shard count"):
            LZ4Engine(mesh=mesh, shards=4)


_FABRIC_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.core.engine import LZ4Engine
    from repro.core.decode_engine import FrameReader, LZ4DecodeEngine
    from repro.core.frame import decode_frame_serial, frame_info
    from repro.core.lz4_types import MAX_BLOCK
    from repro.distributed.sharding import make_mesh_compat
    from tests.test_distributed import _fabric_corpus

    assert len(jax.devices()) == 8
    results = {}
    for shape, axes in [((1, 1), ("data", "model")),
                        ((2, 1), ("data", "model")),
                        ((2, 2), ("data", "model")),
                        ((1, 8), ("data", "model"))]:
        mesh = make_mesh_compat(shape, axes)
        S = shape[0] * shape[1]
        # 7 blocks: uneven against every multi-shard count here
        data = _fabric_corpus(7, seed=S)
        eng = LZ4Engine(mesh=mesh)
        assert eng.shards == S
        frame = eng.compress(data)
        info = frame_info(frame)
        assert info["version"] == 4 and info["shard_count"] == S
        # byte-identity: mesh frame == host-partition oracle frame
        oracle = LZ4Engine(shards=S).compress(data)
        assert frame == oracle, f"mesh != oracle for {shape}"
        # serial oracle round trip
        assert decode_frame_serial(frame) == data
        # sharded decode round trip + cross-shard read_range
        dec = LZ4DecodeEngine(mesh=mesh)
        assert dec.decode(frame) == data
        r = FrameReader(frame, engine=dec)
        b = 2 * MAX_BLOCK
        assert r.read_range(b - 50, 100) == data[b - 50: b + 50]
        results[str(shape)] = {"shards": S,
                               "dispatches": eng.stats.dispatches,
                               "decode_dispatches": dec.stats.dispatches}
    print("RESULT:" + json.dumps({"ok": True, "meshes": results}))
""")


def test_subprocess_mesh_fabric():
    """shard_map compress/decode over mesh shapes (1x1, 2x1, 2x2, 1x8) on 8
    fake devices: v4 round trips, mesh output byte-identical to the
    host-partition oracle, read_range spans crossing shard boundaries."""
    proc = subprocess.run(
        [sys.executable, "-c", _FABRIC_SUBPROC],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src:.", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    result = json.loads(line[len("RESULT:"):])
    assert result["ok"]
    assert set(result["meshes"]) == {"(1, 1)", "(2, 1)", "(2, 2)", "(1, 8)"}
