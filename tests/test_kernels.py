"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, exact equality."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.lz4_types import HASH_PRIME
from repro.kernels import ops
from repro.kernels.ref import fibhash_ref, match_extend_ref


def _np_hash(words: np.ndarray, bits: int) -> np.ndarray:
    return (((words.astype(np.uint64) * HASH_PRIME) & 0xFFFFFFFF) >> (32 - bits)).astype(np.int64)


@pytest.mark.parametrize("n", [2048, 4096, 65536, 3000, 5555])
@pytest.mark.parametrize("bits", [6, 8, 12, 13])
def test_fibhash_pallas_vs_ref(n, bits):
    rng = np.random.default_rng(n * 31 + bits)
    block = rng.integers(0, 256, n + 3, dtype=np.int32)
    w_p, h_p = ops.hash_positions(jnp.asarray(block), hash_bits=bits, use_pallas=True)
    w_r, h_r = ops.hash_positions(jnp.asarray(block), hash_bits=bits, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(w_p), np.asarray(w_r))
    np.testing.assert_array_equal(np.asarray(h_p), np.asarray(h_r))
    # also vs a numpy-computed oracle
    d = block.astype(np.uint64)
    words = (d[:n] | (d[1 : n + 1] << 8) | (d[2 : n + 2] << 16) | (d[3 : n + 3] << 24)) & 0xFFFFFFFF
    np.testing.assert_array_equal(np.asarray(h_p), _np_hash(words, bits))


@pytest.mark.parametrize("n", [1024, 2048, 65536, 2500])
@pytest.mark.parametrize("max_match", [12, 20, 36, 68])
def test_match_extend_pallas_vs_ref(n, max_match):
    rng = np.random.default_rng(n * 7 + max_match)
    # low-entropy data so real matches occur
    block = rng.integers(0, 4, n + max_match, dtype=np.int32)
    cand = rng.integers(0, np.maximum(1, n - 64), n, dtype=np.int32)
    valid = rng.random(n) < 0.5
    out_p = ops.match_lengths(
        jnp.asarray(block), jnp.asarray(cand), jnp.asarray(valid), n,
        max_match=max_match, use_pallas=True,
    )
    out_r = ops.match_lengths(
        jnp.asarray(block), jnp.asarray(cand), jnp.asarray(valid), n,
        max_match=max_match, use_pallas=False,
    )
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    assert np.asarray(out_p)[valid].min() >= 4
    assert np.asarray(out_p).max() <= max_match
    assert (np.asarray(out_p)[~valid] == 0).all()


def test_match_extend_against_python_oracle():
    """Check the bounded prefix semantics against a dead-simple python loop."""
    rng = np.random.default_rng(0)
    n = 2048
    max_match = 36
    block = rng.integers(0, 3, n + max_match, dtype=np.int32)
    cand = rng.integers(0, n - 64, n, dtype=np.int32)
    valid = np.ones(n, dtype=bool)
    out = np.asarray(
        ops.match_lengths(
            jnp.asarray(block), jnp.asarray(cand), jnp.asarray(valid), n,
            max_match=max_match, use_pallas=True,
        )
    )
    for p in rng.integers(0, n, 200):
        q = cand[p]
        cap = min(max_match - 4, n - 5 - (p + 4))
        cap = max(cap, 0)
        l = 0
        while l < cap and block[p + 4 + l] == block[q + 4 + l]:
            l += 1
        assert out[p] == 4 + l, (p, q, out[p], 4 + l)


def test_match_extend_end_of_block_cap():
    """Match end must respect the last-5-literals rule."""
    n = 2048
    block = np.zeros(n + 36, dtype=np.int32)  # all zeros -> max-length matches
    cand = np.zeros(n, dtype=np.int32)
    valid = np.ones(n, dtype=bool)
    out = np.asarray(
        ops.match_lengths(
            jnp.asarray(block), jnp.asarray(cand), jnp.asarray(valid), n,
            max_match=36, use_pallas=True,
        )
    )
    p = np.arange(n)
    expected = 4 + np.clip(n - 5 - (p + 4), 0, 32)
    np.testing.assert_array_equal(out, expected)
