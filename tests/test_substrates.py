"""Substrate tests: checkpoint (LZ4, atomic, corrupt, elastic), data pipeline,
optimizer, gradient compression, serving engine + KV offload, fault policy."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import ShardedTokenPipeline
from repro.distributed.fault import RestartPolicy, StepMonitor
from repro.distributed.sharding import single_device_mesh, use_mesh
from repro.models import lm
from repro.optim import adamw
from repro.optim.grad_compress import ef_init, quantize_with_error_feedback
from repro.serving.engine import Request, ServingEngine, offload_cache, restore_cache


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (128, 64)), jnp.float32),
        "b": {"w": jnp.asarray(np.zeros((1000,)), jnp.float32),  # compressible
              "s": jnp.asarray(3, jnp.int32)},
        "c": [jnp.asarray(rng.integers(0, 255, 5000), jnp.uint8)],
    }


class TestCheckpoint:
    def test_roundtrip_exact(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 5, tree)
        restored, step = ckpt.restore(str(tmp_path), 5, tree)
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compression_helps_on_zeros(self, tmp_path):
        big = {"z": jnp.zeros((300_000,), jnp.float32)}
        ckpt.save(str(tmp_path), 1, big)
        size = os.path.getsize(tmp_path / "ckpt_1" / "data.bin")
        # max ratio with L_max=36 is ~9x (4 encoded bytes per 36-byte match)
        assert size < 1_200_000 / 8

    def test_latest_and_cleanup(self, tmp_path):
        tree = _tree()
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, tree, keep_last=2)
        assert ckpt.latest_step(str(tmp_path)) == 5
        assert not (tmp_path / "ckpt_1").exists()
        assert (tmp_path / "ckpt_4").exists()

    def test_corruption_detected(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 7, tree)
        data = tmp_path / "ckpt_7" / "data.bin"
        raw = bytearray(data.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        data.write_bytes(bytes(raw))
        with pytest.raises(Exception):
            ckpt.restore(str(tmp_path), 7, tree)

    def test_async_save(self, tmp_path):
        tree = _tree()
        t = ckpt.save(str(tmp_path), 9, tree, async_write=True)
        t.join(30)
        restored, _ = ckpt.restore(str(tmp_path), 9, tree)
        np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(restored["a"]))

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto explicit (1-device) shardings — the elastic path."""
        from jax.sharding import PartitionSpec as P
        from jax.sharding import NamedSharding

        tree = _tree()
        ckpt.save(str(tmp_path), 2, tree)
        mesh = single_device_mesh()
        sh = jax.tree.map(lambda x: NamedSharding(mesh, P(*((None,) * x.ndim))), tree)
        restored, _ = ckpt.restore(str(tmp_path), 2, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(restored["a"]))


class TestDataPipeline:
    def test_deterministic_and_compressed(self, tmp_path):
        p1 = ShardedTokenPipeline(str(tmp_path / "d"), 1000, seed=3)
        b1 = p1.batch(0, 4, 64)
        p2 = ShardedTokenPipeline(str(tmp_path / "d"), 1000, seed=3)
        b2 = p2.batch(0, 4, 64)
        np.testing.assert_array_equal(b1, b2)
        assert b1.shape == (4, 64) and b1.min() >= 0 and b1.max() < 1000
        assert p1.compression_ratio() > 1.2  # shards really are LZ4'd

    def test_host_sharding_disjoint(self, tmp_path):
        a = ShardedTokenPipeline(str(tmp_path / "d"), 500, host_id=0, n_hosts=2)
        b = ShardedTokenPipeline(str(tmp_path / "d"), 500, host_id=1, n_hosts=2)
        ba, bb = a.batch(3, 2, 32), b.batch(3, 2, 32)
        assert not np.array_equal(ba, bb)


class TestOptimizer:
    def test_adamw_matches_reference_math(self):
        cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0,
                                grad_clip=1e9, schedule="constant")
        params = {"w": jnp.asarray([1.0, -2.0])}
        state = adamw.init(params)
        g = {"w": jnp.asarray([0.5, 0.25])}
        new_p, state, _ = adamw.update(g, state, params, cfg)
        m = 0.1 * 0.5
        v = 0.05 * 0.25
        upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.95)) + 1e-8)
        np.testing.assert_allclose(float(new_p["w"][0]), 1.0 - 1e-2 * upd, rtol=1e-5)

    def test_schedules(self):
        c = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
        assert float(adamw.lr_at(c, 5)) == pytest.approx(0.5)
        assert float(adamw.lr_at(c, 100)) == pytest.approx(0.0, abs=1e-6)
        w = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd")
        assert float(adamw.lr_at(w, 50)) == pytest.approx(1.0)   # stable phase
        assert float(adamw.lr_at(w, 100)) == pytest.approx(0.01, rel=1e-3)  # decayed

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        g = {"w": jnp.full(4, 100.0)}
        _, _, metrics = adamw.update(g, state, params, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)


class TestGradCompression:
    def test_error_feedback_preserves_sum(self):
        """Quantized + residual == exact gradient (per step identity)."""
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)}
        ef = ef_init(g)
        q, ef2 = quantize_with_error_feedback(g, ef)
        np.testing.assert_allclose(
            np.asarray(q["w"]) + np.asarray(ef2["w"]), np.asarray(g["w"]),
            rtol=1e-6, atol=1e-9,
        )

    def test_convergence_parity_tiny_problem(self):
        """EF-int8 SGD reaches (near) the same optimum as fp32 SGD."""
        rng = np.random.default_rng(1)
        A = jnp.asarray(rng.normal(0, 1, (32, 8)), jnp.float32)
        y = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)

        def loss(w):
            return jnp.mean((A @ w - y) ** 2)

        gfn = jax.jit(jax.grad(loss))

        def run(compress):
            w = jnp.zeros(8)
            ef = {"w": jnp.zeros(8)}
            for _ in range(300):
                g = {"w": gfn(w)}
                if compress:
                    g, ef = quantize_with_error_feedback(g, ef)
                w = w - 0.05 * g["w"]
            return float(loss(w))

        assert run(True) == pytest.approx(run(False), rel=1e-2, abs=1e-4)


class TestServing:
    def test_engine_matches_single_decode(self):
        cfg = get_config("internlm2-1.8b").reduced()
        with use_mesh(single_device_mesh()):
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            eng = ServingEngine(cfg, params, max_batch=2, cache_len=64)
            prompts = [[5, 6, 7, 8, 9], [10, 11, 12, 13, 14]]
            for uid, pr in enumerate(prompts):
                eng.add_request(Request(uid=uid, prompt=pr, max_new_tokens=4))
            done = eng.run()
            # oracle: full forward teacher forcing, greedy
            for r in done:
                toks = list(r.prompt)
                for _ in range(4):
                    logits = lm.forward_logits(
                        params, {"tokens": jnp.asarray([toks], jnp.int32)}, cfg
                    )
                    nxt = int(jnp.argmax(logits[0, -1]))
                    toks.append(nxt)
                assert r.output == toks[len(r.prompt):], r.uid

    def test_kv_offload_roundtrip(self):
        cfg = get_config("internlm2-1.8b").reduced()
        with use_mesh(single_device_mesh()):
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            batch = {"tokens": jnp.asarray([[1, 2, 3, 4] * 8], jnp.int32)}
            cache, _ = lm.prefill(params, batch, cfg, 64)
            blob, stats = offload_cache(cache)
            restored = restore_cache(blob)
            for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert stats["ratio"] > 1.0  # zero-padded cache regions compress


class TestFaultPolicy:
    def test_step_monitor_flags_stragglers(self):
        import time

        mon = StepMonitor(warmup_steps=2, straggler_factor=2.0)
        for i in range(8):
            mon.start()
            time.sleep(0.02 if i != 6 else 0.09)
            m = mon.stop()
            if i == 6:
                assert m["straggler"]
        assert mon.straggler_events == 1

    def test_restart_policy_budget(self):
        pol = RestartPolicy(max_failures=2, backoff_s=0.5)
        assert pol.record_failure() == 0.5
        assert pol.record_failure() == 1.0
        with pytest.raises(RuntimeError):
            pol.record_failure()
