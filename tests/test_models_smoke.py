"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_arch_names, get_config
from repro.distributed.sharding import single_device_mesh, use_mesh
from repro.launch.inputs import make_batch
from repro.models import lm

ARCHS = all_arch_names()
B, S = 2, 32


def _setup(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(1, cfg, B, S)
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg, params, batch = _setup(arch)
    with use_mesh(single_device_mesh()):
        loss, grads = jax.jit(jax.value_and_grad(lm.train_loss), static_argnums=2)(
            params, batch, cfg
        )
        assert jnp.isfinite(loss), arch
        flat = jax.tree.leaves(grads)
        assert all(jnp.all(jnp.isfinite(g)) for g in flat), arch
        # at least 99% of param leaves receive gradient signal somewhere
        nonzero = sum(int(jnp.any(g != 0)) for g in flat)
        assert nonzero >= 0.75 * len(flat), f"{arch}: {nonzero}/{len(flat)} grads nonzero"
        # logits shape
        logits = jax.jit(lm.forward_logits, static_argnums=2)(params, batch, cfg)
        seq_total = S
        assert logits.shape == (B, seq_total, cfg.vocab_size)
        assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(t) after prefill(t0..t-1) must reproduce teacher-forced logits."""
    cfg, params, batch = _setup(arch)
    with use_mesh(single_device_mesh()):
        logits_full = jax.jit(lm.forward_logits, static_argnums=2)(params, batch, cfg)
        cache, logits_pre = jax.jit(lm.prefill, static_argnums=(2, 3))(
            params, batch, cfg, S + 8
        )
        np.testing.assert_allclose(
            np.asarray(logits_pre), np.asarray(logits_full[:, -1]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} prefill logits",
        )
        # one decode step with a new token == teacher forcing over S+1 tokens
        new_tok = jnp.full((B,), 7, jnp.int32)
        logits_dec, cache = jax.jit(lm.decode_step, static_argnums=4)(
            params, cache, new_tok, cache["pos"], cfg
        )
        batch2 = dict(batch)
        batch2["tokens"] = jnp.concatenate([batch["tokens"], new_tok[:, None]], axis=1)
        logits_full2 = jax.jit(lm.forward_logits, static_argnums=2)(params, batch2, cfg)
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full2[:, -1]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} decode logits",
        )


def test_mlstm_chunkwise_equals_sequential():
    from repro.models.recurrent import _mlstm_chunk, _mlstm_sequential

    rng = np.random.default_rng(0)
    B_, S_, H, p = 2, 64, 3, 8
    q, k, v = (jnp.asarray(rng.normal(0, 1, (B_, S_, H, p)), jnp.float32) for _ in range(3))
    i_g = jnp.asarray(rng.normal(0, 1, (B_, S_, H)), jnp.float32)
    f_g = jnp.asarray(rng.normal(2, 1, (B_, S_, H)), jnp.float32)
    h_chunk, fin_c = _mlstm_chunk(q, k, v, i_g, f_g, chunk=16)
    h_seq, fin_s = _mlstm_sequential(q, k, v, i_g, f_g)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq), rtol=1e-4, atol=1e-4)
    # carried states agree too (decode continues correctly after prefill)
    np.testing.assert_allclose(np.asarray(fin_c[2]), np.asarray(fin_s["m"]), rtol=1e-4, atol=1e-4)


def test_rglru_scan_matches_step():
    from repro.configs.base import get_config
    from repro.models import recurrent as rec

    cfg = get_config("recurrentgemma-9b").reduced()
    p = rec.rglru_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (2, 12, cfg.d_model)), jnp.float32)
    y_par, state_par = rec.rglru_block(p, x, cfg)
    state = rec.rglru_state_init(cfg, 2, jnp.float32)
    ys = []
    for t in range(12):
        y_t, state = rec.rglru_step(p, x[:, t : t + 1], cfg, state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_par["h"]), np.asarray(state["h"]), rtol=1e-4, atol=1e-4)


def test_exact_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert sum(len(s.unit) * s.repeats for s in cfg.segments) == L, arch
        assert cfg.d_model == d and cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == V, arch
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("gemma2-9b").attn_softcap == 50.0
    assert get_config("qwen3-1.7b").qk_norm
