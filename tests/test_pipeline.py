"""Pipeline parallelism: pipelined output == sequential stage composition."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(2, 2) == pytest.approx(1 / 3)
    assert bubble_fraction(4, 16) == pytest.approx(3 / 19)
    assert bubble_fraction(1, 8) == 0.0


_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_apply
    from repro.distributed.sharding import use_mesh

    from repro.distributed.sharding import make_mesh_compat
    mesh = make_mesh_compat((4,), ("pod",))
    rng = np.random.default_rng(0)
    S, d = 4, 16
    W = jnp.asarray(rng.normal(0, 0.5, (S, d, d)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (S, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (8, d)), jnp.float32)

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    params = {"w": W, "b": b}
    with use_mesh(mesh):
        y_pipe = jax.jit(
            lambda pp, xx: pipeline_apply(stage_fn, pp, xx, n_micro=4)
        )(params, x)
    y_seq = x
    for s in range(S):
        y_seq = stage_fn({"w": W[s], "b": b[s]}, y_seq)
    err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
    print("RESULT:" + json.dumps({"max_err": err}))
""")


def test_pipeline_matches_sequential_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    assert json.loads(line[len("RESULT:"):])["max_err"] < 1e-6
