"""Device-resident decode (PR-4 acceptance surface).

  * `execute_device_plan` (the NumPy twin of the device algorithm —
    per-byte source maps + pointer doubling + one gather) is bit-identical
    to `execute_plan` on compressor output, overlap-heavy chains, and
    adversarial random plans;
  * `kernels.ops.decode_gather` (jnp fallback AND Pallas kernel) equals
    both host oracles for every `rounds` in {exact, worst-case};
  * `LZ4DecodeEngine(executor="device")` decode is bit-identical to
    `decode_frame_serial` on the frame corpora, with `host_bytes` counting
    exactly the decoded payload;
  * a trimmed byte-flip/truncation sweep over the fuzz corpora: corrupt
    frames must raise through the device executor exactly like the serial
    oracle — never decode silently to different bytes;
  * fixed-shape caps: plans that overflow `DevicePlanCaps` fall back to
    host execution per block (counted, still bit-identical);
  * the accelerator-to-accelerator restore path: `decode_to_device`,
    `FrameReader.read_range_device`, and `OffloadedCacheReader(
    to_device=True)` return device arrays with zero device->host content
    traffic — since PR 5 even with ``verify=True``, whose CRC32 runs
    in-graph (`kernels.ops.crc32_bytes`) and syncs only a 4-byte checksum;
    corrupt content must still be rejected exactly like the serial oracle.
"""
import numpy as np
import pytest

from repro.core import (
    DevicePlanCaps,
    DevicePlanOverflow,
    FrameFormatError,
    LZ4DecodeEngine,
    LZ4Engine,
    Sequence,
    decode_frame_serial,
    encode_block,
    execute_device_plan,
    execute_plan,
    plan_block_fast,
    to_device_plan,
)
from repro.core.decode_plan import MAX_RESOLVE_ROUNDS
from repro.core.lz4_types import MAX_BLOCK


def _rng():
    return np.random.default_rng(20260801)


def _encode_oracle(data: bytes) -> bytes:
    from repro.core import compress_windowed

    res = compress_windowed(data, hash_bits=8, max_match=36)
    return encode_block(data, res.sequences)


def _block_corpus() -> dict[str, bytes]:
    rng = _rng()
    return {
        "text": b"the quick brown fox jumps over the lazy dog. " * 400,
        "zeros": b"\x00" * MAX_BLOCK,        # RLE chain: depth-65535 resolve
        "low_entropy": rng.integers(0, 4, 30000, np.uint8).tobytes(),
        "structured": bytes(rng.integers(0, 16, 64, np.uint8)) * 40,
        "literal_tail": rng.integers(0, 256, 700, np.uint8).tobytes()
                        + b"Q" * 900,
        "one": b"\x51",
    }


def _frame_corpus() -> dict[str, bytes]:
    rng = _rng()
    return {
        "empty": b"",
        "tiny": b"xyz",
        "multi_text": b"spam and eggs and ham, " * 12000,
        "zeros_multi": b"\x00" * (2 * MAX_BLOCK + 17),
        "raw_multi": rng.integers(0, 256, MAX_BLOCK + 5000, np.uint8).tobytes(),
        "mixed": ((b"ab" * MAX_BLOCK)[:MAX_BLOCK - 7]
                  + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()
                  + b"pattern-" * 4000),
    }


@pytest.fixture(scope="module")
def engine():
    return LZ4Engine(micro_batch=4)


@pytest.fixture(scope="module")
def device_engine():
    return LZ4DecodeEngine(executor="device", micro_batch=4)


# ---------------------------------------------------------------------------
# Host oracle of the device algorithm vs execute_plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(_block_corpus().keys()))
def test_device_oracle_equals_execute_plan(name):
    blk = _encode_oracle(_block_corpus()[name])
    plan = plan_block_fast(blk)
    assert execute_device_plan(blk, plan).tobytes() == \
        execute_plan(blk, plan).tobytes()


def test_device_oracle_overlap_chains():
    # Self-overlapping matches (offset < length) and chains of matches
    # reading each other's output: the wave scheduler's hard cases, which
    # pointer doubling must resolve without any fallback.
    for offset, mlen, lead in [(1, 95, b"a"), (2, 40, b"ab"), (3, 100, b"xyz"),
                               (1, 5000, b"z"), (5, 6, b"olapp")]:
        data = lead + (lead * (mlen // len(lead) + 2))[:mlen]
        seq = [Sequence(0, len(lead), mlen, offset), Sequence(len(lead) + mlen, 0)]
        blk = encode_block(data, seq)
        plan = plan_block_fast(blk)
        assert execute_device_plan(blk, plan).tobytes() == data


def test_device_oracle_random_plans():
    rng = _rng()
    for trial in range(25):
        src = bytes(rng.integers(0, 256, 4096, np.uint8))
        data = bytearray()
        seqs = []
        cursor = 0
        for _ in range(int(rng.integers(1, 40))):
            lit = int(rng.integers(0, 30))
            lit_start = len(data)
            data += src[cursor:cursor + lit]
            cursor += lit
            if len(data) == 0:
                continue
            offset = int(rng.integers(1, min(len(data), 65535) + 1))
            mlen = int(rng.integers(4, 60))
            seqs.append(Sequence(lit_start, lit, mlen, offset))
            s = len(data) - offset
            for j in range(mlen):
                data.append(data[s + j])
        seqs.append(Sequence(len(data), 0))
        data = bytes(data)
        blk = encode_block(data, seqs)
        plan = plan_block_fast(blk)
        assert execute_device_plan(blk, plan).tobytes() == data, trial


# ---------------------------------------------------------------------------
# DevicePlan shape/wave semantics
# ---------------------------------------------------------------------------

def test_device_plan_wave_semantics():
    # Pure-literal block: zero resolve rounds.
    plan = plan_block_fast(_encode_oracle(_rng().integers(
        0, 256, 2500, np.uint8).tobytes()))
    dp = to_device_plan(plan)
    if dp.n_match == 0:
        assert dp.n_waves == 0
    # The all-zeros RLE chain needs the full worst-case depth.
    plan_z = plan_block_fast(_encode_oracle(b"\x00" * MAX_BLOCK))
    dp_z = to_device_plan(plan_z)
    assert dp_z.n_waves == MAX_RESOLVE_ROUNDS
    assert dp_z.wave[:dp_z.n_match].max() == MAX_RESOLVE_ROUNDS
    # Padding rows are zeros, wave padding is -1.
    assert (dp_z.wave[dp_z.n_match:] == -1).all()
    assert (dp_z.match_len[dp_z.n_match:] == 0).all()
    # compute_waves=False pins the static worst case.
    dp_s = to_device_plan(plan_z, compute_waves=False)
    assert dp_s.n_waves == MAX_RESOLVE_ROUNDS and (dp_s.wave == -1).all()
    assert dp_z.n_sequences == dp_z.n_lit + dp_z.n_match == plan_z.n_sequences


def test_device_plan_overflow():
    plan = plan_block_fast(_encode_oracle(b"overflow check " * 1000))
    tiny = DevicePlanCaps(max_lit=2, max_match=2)
    with pytest.raises(DevicePlanOverflow):
        to_device_plan(plan, tiny)


def test_device_engine_caps_fallback(engine):
    # An engine with absurdly small caps must still decode bit-exactly —
    # every block through the per-block host fallback, and counted.
    data = b"fallback parity " * 20000
    frame = engine.compress(data)
    de = LZ4DecodeEngine(executor="device",
                         caps=DevicePlanCaps(max_lit=2, max_match=2))
    assert de.decode(frame) == data
    assert de.stats.fallback_blocks == de.stats.blocks
    assert de.stats.device_blocks == 0


@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
def test_specplan_caps_fallback(engine, use_pallas):
    # Same caps-overflow semantics under the SPECULATIVE planner: the
    # in-graph status vector flags the overflow per block, the engine
    # replans that block on host (counted in fallback_blocks), and the
    # output stays bit-exact.  device_blocks counts only blocks that
    # actually finished in-graph: zero here.
    data = b"speculative fallback parity " * 20000
    frame = engine.compress(data)
    de = LZ4DecodeEngine(executor="device", plan_on_device=True,
                         use_pallas=use_pallas,
                         caps=DevicePlanCaps(max_lit=2, max_match=2))
    assert de.decode(frame) == data
    assert de.stats.fallback_blocks == de.stats.blocks
    assert de.stats.device_blocks == 0
    # And with default caps the same engine config takes zero fallbacks.
    ok = LZ4DecodeEngine(executor="device", plan_on_device=True,
                         use_pallas=use_pallas)
    assert ok.decode(frame) == data
    assert ok.stats.fallback_blocks == 0
    assert ok.stats.device_blocks == ok.stats.blocks


# ---------------------------------------------------------------------------
# decode_gather: jnp fallback AND Pallas kernel vs the oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["text", "zeros", "low_entropy", "one"])
def test_decode_gather_both_kernels_bit_identical(name):
    import jax.numpy as jnp

    from repro.kernels.ops import decode_gather

    data = _block_corpus()[name]
    blk = _encode_oracle(data)
    plan = plan_block_fast(blk)
    dp = to_device_plan(plan)
    buf = np.zeros(dp.caps.blk_cap, np.uint8)
    buf[: len(blk)] = np.frombuffer(blk, np.uint8)
    args = (jnp.asarray(buf),
            jnp.asarray(dp.lit_src), jnp.asarray(dp.lit_dst),
            jnp.asarray(dp.lit_len), jnp.asarray(dp.match_dst),
            jnp.asarray(dp.match_off), jnp.int32(dp.n_lit),
            jnp.int32(dp.n_match), jnp.int32(dp.out_size))
    for rounds in {dp.n_waves, MAX_RESOLVE_ROUNDS}:
        ref = np.asarray(decode_gather(*args, out_cap=dp.caps.out_cap,
                                       rounds=rounds))
        pal = np.asarray(decode_gather(*args, out_cap=dp.caps.out_cap,
                                       rounds=rounds, use_pallas=True))
        assert ref[: dp.out_size].tobytes() == data, (name, rounds)
        assert not ref[dp.out_size:].any()
        assert (ref == pal).all(), (name, rounds)


def test_device_engine_pallas_path(engine):
    data = b"pallas decode parity " * 9000
    frame = engine.compress(data)
    de = LZ4DecodeEngine(executor="device", use_pallas=True, micro_batch=2)
    assert de.decode(frame) == data
    assert de.stats.device_blocks == de.stats.blocks


# ---------------------------------------------------------------------------
# Engine bit-identity + transfer accounting
# ---------------------------------------------------------------------------

def test_device_engine_bit_identical(engine, device_engine):
    for name, data in _frame_corpus().items():
        frame = engine.compress(data)
        got = device_engine.decode(frame)
        assert got == data, name
        assert got == decode_frame_serial(frame), name


def test_device_engine_host_bytes_exact(engine, device_engine):
    # The device executor slice-fetches rows to their true usize: fetched
    # bytes == decoded payload of the non-raw blocks, nothing padded.
    data = b"exact transfer accounting " * 11000  # multi-block, compressible
    frame = engine.compress(data)
    assert device_engine.decode(frame) == data
    st = device_engine.stats
    assert st.fallback_blocks == 0 and st.raw_blocks == 0
    assert st.host_bytes == len(data)
    assert st.dispatches == -(-st.blocks // device_engine.micro_batch)


def test_device_engine_adaptive_vs_static_rounds(engine):
    data = b"rounds bucketing " * 15000
    frame = engine.compress(data)
    adaptive = LZ4DecodeEngine(executor="device", adaptive_rounds=True)
    static = LZ4DecodeEngine(executor="device", adaptive_rounds=False)
    assert adaptive.decode(frame) == static.decode(frame) == data


def test_device_decode_blocks_plain(engine, device_engine):
    data = b"plain blocks " * 12000
    payloads = engine.compress_to_blocks(data)
    usizes = [min(MAX_BLOCK, len(data) - i * MAX_BLOCK)
              for i in range(len(payloads))]
    out = device_engine.decode_blocks(payloads, [False] * len(payloads),
                                      usizes=usizes)
    assert b"".join(out) == data
    with pytest.raises(Exception):
        device_engine.decode_blocks([payloads[0]], [False],
                                    usizes=[usizes[0] - 1])


# ---------------------------------------------------------------------------
# Corruption through the device executor (trimmed fuzz sweep)
# ---------------------------------------------------------------------------

def _assert_device_rejects(de, mutant: bytes, where: str,
                           original: bytes | None = None):
    try:
        out = de.decode(mutant)
    except FrameFormatError:
        return
    except Exception as e:
        pytest.fail(f"{where}: raised {type(e).__name__}: {e}")
    if original is None or out != original:
        pytest.fail(f"{where}: decoded corrupt frame silently")


def test_device_corruption_never_silent(engine, device_engine):
    rng = _rng()
    corpora = {
        "text": b"fuzz me gently, " * 900,
        "multi": b"the quick brown fox " * 9000,
        "zeros": b"\x00" * (MAX_BLOCK + 5),
        "raw": rng.integers(0, 256, 3000, np.uint8).tobytes(),
    }
    for name, data in corpora.items():
        frame = engine.compress(data)
        assert device_engine.decode(frame) == data
        n = len(frame)
        # Header/table region densely, payload strided — every mutant must
        # behave identically to the serial oracle: reject, or (rarely)
        # decode to the SAME bytes.
        positions = list(range(min(48, n))) + \
            list(range(48, n, max(1, n // 40))) + [n - 1]
        for pos in positions:
            mutant = bytearray(frame)
            mutant[pos] ^= 0x40
            mutant = bytes(mutant)
            try:
                oracle = decode_frame_serial(mutant)
            except FrameFormatError:
                oracle = None
            _assert_device_rejects(device_engine, mutant,
                                   f"{name}: flip {pos}", original=data)
            if oracle is not None:
                # Oracle accepted (provably-harmless flip): device executor
                # must produce the identical bytes.
                assert device_engine.decode(mutant) == oracle, (name, pos)
        for cut in range(0, n, max(1, n // 15)):
            _assert_device_rejects(device_engine, frame[:cut],
                                   f"{name}: truncate {cut}")


def test_device_crc_detects_parse_valid_corruption(engine, device_engine):
    # Flip deep in a literal run: still a valid token stream, only the
    # content CRC can catch it — including on the device path, where the
    # decoded bytes are fetched back for verification.
    data = b"integrity through the device path " * 6000
    frame = bytearray(engine.compress(data))
    frame[-7] ^= 0x40
    with pytest.raises(FrameFormatError):
        device_engine.decode(bytes(frame))


# ---------------------------------------------------------------------------
# Accelerator-to-accelerator restore
# ---------------------------------------------------------------------------

def test_decode_to_device_matches_and_transfers_nothing(engine, device_engine):
    import jax

    data = _frame_corpus()["mixed"]
    frame = engine.compress(data)
    dev = device_engine.decode_to_device(frame)
    assert isinstance(dev, jax.Array)
    assert np.asarray(dev).tobytes() == data
    # verify=True checks CRCs IN-GRAPH (slice-by-8, ops.crc32_bytes): the
    # decoded content itself never crosses to the host even when verified.
    assert device_engine.stats.host_bytes == 0
    # verify=False: additionally skips the per-block checksum sync.
    dev2 = device_engine.decode_to_device(frame, verify=False)
    assert device_engine.stats.host_bytes == 0
    assert np.asarray(dev2).tobytes() == data
    # Corruption still raises when verification is on — caught by the
    # device-computed checksum, without fetching the content.
    mutant = bytearray(frame)
    mutant[-3] ^= 0x08
    with pytest.raises(FrameFormatError):
        device_engine.decode_to_device(bytes(mutant))


def test_decode_to_device_crc_parity_with_serial_oracle(engine, device_engine):
    # Payload byte flips through the VERIFIED device restore must behave
    # exactly like the serial oracle: reject, or (harmless-flip corner)
    # decode to the identical bytes — all without fetching content.
    data = b"device crc parity " * 7000
    frame = engine.compress(data)
    n = len(frame)
    payload_start = n // 2  # well past the header/table, inside payloads
    for pos in range(payload_start, n, max(1, n // 25)):
        mutant = bytearray(frame)
        mutant[pos] ^= 0x40
        mutant = bytes(mutant)
        try:
            oracle = decode_frame_serial(mutant)
        except FrameFormatError:
            oracle = None
        try:
            got = np.asarray(
                device_engine.decode_to_device(mutant)).tobytes()
        except FrameFormatError:
            assert oracle is None, f"device rejected, oracle accepted @ {pos}"
            continue
        assert oracle is not None, f"device accepted, oracle rejected @ {pos}"
        assert got == oracle, pos
        assert device_engine.stats.host_bytes == 0


def test_decode_to_device_rejects_lying_usize_without_verify(device_engine):
    # A table entry claiming more bytes than the block decodes to must be
    # rejected even with verify=False (the plan knows the exact size before
    # dispatch) — otherwise multi-block device reads would slice at wrong
    # offsets.  The host paths catch this in check_block; parity required.
    from repro.core import block_crc, encode_frame

    data = b"short block " * 50  # 600 bytes
    payload = _encode_oracle(data)
    frame = encode_frame([payload], [len(data) + 20], [False],
                         checksums=[block_crc(data)])
    with pytest.raises(FrameFormatError, match="table says"):
        device_engine.decode_to_device(frame, verify=False)
    with pytest.raises(FrameFormatError):
        device_engine.decode(frame)


def test_read_range_device(engine, device_engine):
    from repro.core import FrameReader

    data = _frame_corpus()["multi_text"]
    frame = engine.compress(data)
    reader = FrameReader(frame, engine=device_engine)
    rng = _rng()
    cases = [(0, 0), (0, 1), (len(data), 0), (len(data) - 1, 1),
             (MAX_BLOCK - 3, 7), (MAX_BLOCK, MAX_BLOCK)]
    cases += [(int(rng.integers(0, len(data))), int(rng.integers(0, 9000)))
              for _ in range(8)]
    for start, length in cases:
        length = min(length, len(data) - start)
        got = np.asarray(reader.read_range_device(start, length)).tobytes()
        assert got == data[start: start + length], (start, length)


def test_offloaded_reader_to_device(engine):
    import jax
    import jax.numpy as jnp

    from repro.serving.engine import OffloadedCacheReader, offload_cache

    rng = _rng()
    cache = {
        # "a_pos" sorts before "k", so the COMPRESSED leaf decodes last —
        # the per-leaf stats assertions below see it, not the tiny raw one.
        "a_pos": jnp.asarray(np.arange(7, dtype=np.int32)),
        "k": jnp.asarray((rng.integers(0, 3, (2, 128, 64)) * 0.5)
                         .astype(np.float32)),
    }
    blob, _ = offload_cache(cache)
    de = LZ4DecodeEngine(executor="device")
    rdr = OffloadedCacheReader(blob, decode_engine=de, to_device=True)
    restored = rdr.restore()
    for key in cache:
        got = restored[key]
        assert isinstance(got, jax.Array)
        assert got.dtype == cache[key].dtype and got.shape == cache[key].shape
        assert (np.asarray(got) == np.asarray(cache[key])).all(), key
    # Partial leaf slice stays on device and matches the host reader.
    host = OffloadedCacheReader(blob)
    k_leaf = 1  # flatten order: a_pos, k
    sl = rdr.read_leaf(k_leaf, start=1000, count=500)
    assert isinstance(sl, jax.Array)
    assert (np.asarray(sl) == host.read_leaf(k_leaf, 1000, 500)).all()
    # verify=False makes the whole restore accelerator-to-accelerator:
    # zero plaintext bytes fetched to host for the compressed leaves.
    de2 = LZ4DecodeEngine(executor="device")
    fast = OffloadedCacheReader(blob, decode_engine=de2, to_device=True,
                                verify=False)
    restored2 = fast.restore()
    assert de2.stats.host_bytes == 0
    for key in cache:
        assert (np.asarray(restored2[key]) == np.asarray(cache[key])).all()


def test_checkpoint_restore_device_executor(tmp_path, engine):
    import jax.numpy as jnp

    from repro.checkpoint import checkpoint as ck

    rng = _rng()
    tree = {"w": jnp.asarray((rng.integers(0, 7, (257, 129)) * 0.125)
                             .astype(np.float32)),
            "b": jnp.asarray(np.arange(17, dtype=np.int32))}
    ck.save(str(tmp_path), 5, tree)
    de = LZ4DecodeEngine(executor="device")
    out, step = ck.restore(str(tmp_path), 5, tree, decode_engine=de)
    assert step == 5
    for key in tree:
        assert (np.asarray(out[key]) == np.asarray(tree[key])).all(), key
