"""Dry-run tooling tests: HLO parsers, cell bookkeeping, probe linearity."""
import json
import subprocess
import sys
import textwrap
import types

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import _shape_bytes, parse_collectives, parse_dot_bytes
from repro.launch.roofline import model_param_count
from repro.configs.base import get_config

HLO = textwrap.dedent("""
    %x = f32[16,128]{1,0} parameter(0)
    %ag = f32[16,2048]{1,0} all-gather(f32[16,128]{1,0} %x), replica_groups={{0,1}}, dimensions={1}
    %ar = (bf16[256]{0}, bf16[256]{0}) all-reduce(bf16[256]{0} %a, bf16[256]{0} %b), to_apply=%sum
    %rs = f32[8,128]{1,0} reduce-scatter(f32[128,128]{1,0} %y), dimensions={0}
    %cp = u8[1024]{0} collective-permute(u8[1024]{0} %z), source_target_pairs={{0,1}}
    %d = f32[64,32]{1,0} dot(f32[64,16]{1,0} %p, f32[16,32]{1,0} %q), lhs_contracting_dims={1}
    %notacoll = f32[4]{0} add(f32[4]{0} %m, f32[4]{0} %n)
""")


class TestParsers:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
        assert _shape_bytes("(bf16[256]{0}, s8[4]{0})") == 256 * 2 + 4
        assert _shape_bytes("pred[]") == 1  # scalar => dims empty

    def test_parse_collectives(self):
        stats = parse_collectives(HLO)
        assert stats["all-gather"]["count"] == 1
        assert stats["all-gather"]["bytes"] == 16 * 2048 * 4
        assert stats["all-reduce"]["bytes"] == 2 * 256 * 2
        assert stats["reduce-scatter"]["count"] == 1
        assert stats["collective-permute"]["bytes"] == 1024
        assert "dot" not in stats and "add" not in stats

    def test_parse_dot_bytes(self):
        # operands + result of the dot line only
        assert parse_dot_bytes(HLO) == (64 * 32 + 64 * 16 + 16 * 32) * 4

    def test_shape_bytes_scalar_pred(self):
        assert _shape_bytes("pred[1,1,256]{1,0,2}") == 256


class TestModelFlops:
    def test_param_counts_close_to_nominal(self):
        # analytic N within 40% of the arch's nominal size (non-embedding
        # N differs from marketing numbers; this guards gross errors)
        nominal = {
            "internlm2-1.8b": 1.8e9, "qwen3-1.7b": 1.7e9, "minicpm-2b": 2.4e9,
            "gemma2-9b": 9e9, "mixtral-8x7b": 46e9, "mixtral-8x22b": 140e9,
        }
        for arch, n in nominal.items():
            total, active = model_param_count(get_config(arch))
            assert 0.5 * n < total < 1.6 * n, (arch, total)
            assert active <= total

    def test_moe_active_fraction(self):
        total, active = model_param_count(get_config("mixtral-8x7b"))
        assert active < 0.45 * total  # top-2 of 8 experts dominate params


def test_probe_linearity_subprocess():
    """Per-layer cost deltas are linear in repeats (the probe assumption)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import json, dataclasses
        import jax
        from repro.launch.dryrun import _with_repeats, _lower_cell, _cost_of
        from repro.configs.base import get_config, ShapeConfig
        from repro.distributed.sharding import use_mesh

        from repro.distributed.sharding import make_mesh_compat
        mesh = make_mesh_compat((4, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_config("internlm2-1.8b"), d_model=256, n_heads=8, head_dim=32,
            n_kv_heads=4, d_ff=512, vocab_size=2048, fsdp=True)
        shape = ShapeConfig("t", seq_len=256, global_batch=8, mode="train")
        with use_mesh(mesh):
            f = [_cost_of(_lower_cell(_with_repeats(cfg, [r]), shape, mesh).compile())["flops"]
                 for r in (2, 3, 4)]
        d1, d2 = f[1] - f[0], f[2] - f[1]
        print("RESULT:" + json.dumps({"d1": d1, "d2": d2}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    r = json.loads(line[len("RESULT:"):])
    assert r["d1"] > 0
    assert abs(r["d1"] - r["d2"]) / r["d1"] < 0.05, r
