"""Property-based tests (hypothesis) for the system's core invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from repro.core import (
    compress_greedy,
    compress_windowed,
    decode_block,
    encode_block,
    plan_coverage,
    plan_size,
)
from repro.core.jax_compressor import compress_block_records, pad_block, records_to_plan

# Byte-stream strategies with different redundancy structure.
_raw = st.binary(min_size=0, max_size=4096)
_structured = st.builds(
    lambda unit, reps, tail: unit * reps + tail,
    st.binary(min_size=1, max_size=64),
    st.integers(min_value=1, max_value=200),
    st.binary(min_size=0, max_size=32),
)
_low_entropy = st.builds(
    lambda seed, n: np.random.default_rng(seed).integers(0, 3, n, dtype=np.uint8).tobytes(),
    st.integers(0, 2**31),
    st.integers(0, 4096),
)
_any_data = st.one_of(_raw, _structured, _low_entropy)


@given(_any_data)
@settings(max_examples=60, deadline=None)
def test_greedy_roundtrip(data):
    plan = compress_greedy(data, hash_bits=10)
    assert plan_coverage(plan) == len(data)
    assert decode_block(encode_block(data, plan)) == data


@given(_any_data, st.sampled_from([6, 8, 12]), st.sampled_from([12, 36, None]))
@settings(max_examples=60, deadline=None)
def test_windowed_roundtrip(data, bits, max_match):
    res = compress_windowed(data, hash_bits=bits, max_match=max_match)
    assert plan_coverage(res.sequences) == len(data)
    assert decode_block(encode_block(data, res.sequences)) == data
    # no match may start in the last 12 bytes or end past len-5
    for s in res.sequences[:-1]:
        start = s.lit_start + s.lit_len
        assert start <= len(data) - 12
        assert start + s.match_len <= len(data) - 5
        assert 1 <= s.offset <= 65535


@given(_any_data)
@settings(max_examples=25, deadline=None)
def test_jax_engine_equals_golden_and_roundtrips(data):
    golden = compress_windowed(data, hash_bits=8, max_match=36)
    buf, n = pad_block(data)
    rec = compress_block_records(jnp.asarray(buf), jnp.int32(n))
    plan = records_to_plan(rec, n)
    assert plan_size(plan) == int(rec.size) == plan_size(golden.sequences)
    assert decode_block(encode_block(data, plan)) == data


@given(_any_data)
@settings(max_examples=30, deadline=None)
def test_scheme_ratio_ordering(data):
    """Restricting the compressor can never shrink the output below the less
    restricted scheme's output: greedy <= single-match <= single+capped."""
    greedy = plan_size(compress_greedy(data, hash_bits=8))
    single = plan_size(compress_windowed(data, hash_bits=8, max_match=None).sequences)
    combined = plan_size(compress_windowed(data, hash_bits=8, max_match=36).sequences)
    assert greedy <= single <= combined
    # worst case bound: one token per 15-ish literals overhead
    assert combined <= len(data) + len(data) // 255 + 16
