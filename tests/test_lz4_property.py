"""Property-based tests for the system's core invariants.

Two flavours: hypothesis-driven generative tests (skipped individually when
hypothesis is not installed in the image) and seeded differential sweeps
(always run) pinning every datapath variant — candidate impl x shard count x
drain mode — to byte-identical frames.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on image contents
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Placeholder so module-level strategy expressions still evaluate;
        every @given test is skipped before these stubs are ever drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _StrategyStub()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed in this image")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.core import (
    compress_greedy,
    compress_windowed,
    decode_block,
    encode_block,
    plan_coverage,
    plan_size,
)
from repro.core.jax_compressor import compress_block_records, pad_block, records_to_plan

# Byte-stream strategies with different redundancy structure.
_raw = st.binary(min_size=0, max_size=4096)
_structured = st.builds(
    lambda unit, reps, tail: unit * reps + tail,
    st.binary(min_size=1, max_size=64),
    st.integers(min_value=1, max_value=200),
    st.binary(min_size=0, max_size=32),
)
_low_entropy = st.builds(
    lambda seed, n: np.random.default_rng(seed).integers(0, 3, n, dtype=np.uint8).tobytes(),
    st.integers(0, 2**31),
    st.integers(0, 4096),
)
_any_data = st.one_of(_raw, _structured, _low_entropy)


@given(_any_data)
@settings(max_examples=60, deadline=None)
def test_greedy_roundtrip(data):
    plan = compress_greedy(data, hash_bits=10)
    assert plan_coverage(plan) == len(data)
    assert decode_block(encode_block(data, plan)) == data


@given(_any_data, st.sampled_from([6, 8, 12]), st.sampled_from([12, 36, None]))
@settings(max_examples=60, deadline=None)
def test_windowed_roundtrip(data, bits, max_match):
    res = compress_windowed(data, hash_bits=bits, max_match=max_match)
    assert plan_coverage(res.sequences) == len(data)
    assert decode_block(encode_block(data, res.sequences)) == data
    # no match may start in the last 12 bytes or end past len-5
    for s in res.sequences[:-1]:
        start = s.lit_start + s.lit_len
        assert start <= len(data) - 12
        assert start + s.match_len <= len(data) - 5
        assert 1 <= s.offset <= 65535


@given(_any_data)
@settings(max_examples=25, deadline=None)
def test_jax_engine_equals_golden_and_roundtrips(data):
    golden = compress_windowed(data, hash_bits=8, max_match=36)
    buf, n = pad_block(data)
    rec = compress_block_records(jnp.asarray(buf), jnp.int32(n))
    plan = records_to_plan(rec, n)
    assert plan_size(plan) == int(rec.size) == plan_size(golden.sequences)
    assert decode_block(encode_block(data, plan)) == data


@given(_any_data)
@settings(max_examples=30, deadline=None)
def test_scheme_ratio_ordering(data):
    """Restricting the compressor can never shrink the output below the less
    restricted scheme's output: greedy <= single-match <= single+capped."""
    greedy = plan_size(compress_greedy(data, hash_bits=8))
    single = plan_size(compress_windowed(data, hash_bits=8, max_match=None).sequences)
    combined = plan_size(compress_windowed(data, hash_bits=8, max_match=36).sequences)
    assert greedy <= single <= combined
    # worst case bound: one token per 15-ish literals overhead
    assert combined <= len(data) + len(data) // 255 + 16


# ---------------------------------------------------------------------------
# Differential fabric tests: frame bytes must be IDENTICAL across candidate
# impls x shard counts x drain modes (the sharded fabric's merge stage and
# every datapath variant are pinned to one another, not just to "decodes
# back").  Seeded adversarial corpora, not hypothesis: each engine config
# costs a jit compile, so the sweep is deterministic and shared.
# ---------------------------------------------------------------------------

from repro.core import LZ4Engine  # noqa: E402
from repro.core.frame import decode_frame_serial, frame_info  # noqa: E402
from repro.core.jax_compressor import CANDIDATE_IMPLS  # noqa: E402
from repro.core.lz4_types import MAX_BLOCK  # noqa: E402

_SHARD_COUNTS = (1, 2, 4)
_DRAINS = ("sliced", "full")


def _adversarial_corpus(seed: int) -> bytes:
    """RLE runs, matches straddling 2048-byte tile boundaries, structured
    text, and an incompressible tail — 3 blocks and change."""
    rng = np.random.default_rng(seed)
    parts = []
    # RLE runs (extension-byte boundaries at lengths near 15/270)
    for n in (14, 15, 19, 270, 271, 5000):
        parts.append(bytes([int(rng.integers(0, 256))]) * n)
    # tile-straddle: an 8-byte unit repeating ACROSS the 2048 boundary
    unit = bytes(rng.integers(0, 256, 8, dtype=np.uint8))
    parts.append(unit * 600)  # 4800 B, crosses two tile boundaries
    # structured text
    parts.append(b"shard fabric differential %d " % seed * 400)
    # incompressible tail
    parts.append(rng.integers(0, 256, 70000, dtype=np.uint8).tobytes())
    data = b"".join(parts)
    # pad to 3 blocks + a partial fourth so shard counts 2 and 4 are uneven
    reps = (3 * MAX_BLOCK + MAX_BLOCK // 2) // len(data) + 1
    return (data * reps)[: 3 * MAX_BLOCK + MAX_BLOCK // 2]


def _payload_bytes(frame: bytes) -> list[bytes]:
    """Per-block payload bytes (shard/version metadata stripped)."""
    return [frame[b["offset"]: b["offset"] + b["csize"]]
            for b in frame_info(frame)["blocks"]]


@pytest.mark.parametrize("seed", [0, 1])
def test_frame_identity_impl_x_shards_x_drain(seed):
    data = _adversarial_corpus(seed)
    reference = {}  # shards -> frame from the first (impl, drain) combo
    ref_payloads = None
    for shards in _SHARD_COUNTS:
        for impl in CANDIDATE_IMPLS:
            for drain in _DRAINS:
                eng = LZ4Engine(candidate_impl=impl, drain=drain,
                                shards=shards)
                frame = eng.compress(data)
                # identity across impls and drains (fixed shard count)
                if shards not in reference:
                    reference[shards] = frame
                    assert decode_frame_serial(frame) == data
                else:
                    assert frame == reference[shards], \
                        f"impl={impl} drain={drain} shards={shards}"
        # across shard counts the container header differs (v4 shard
        # column) but every block's payload bytes must be identical
        payloads = _payload_bytes(reference[shards])
        if ref_payloads is None:
            ref_payloads = payloads
        else:
            assert payloads == ref_payloads, f"shards={shards}"


@given(st.integers(0, 2**31), st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_sharded_roundtrip_random(seed, shards):
    """Any byte stream round-trips through the sharded writer and both
    readers (serial oracle and engine)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 3 * MAX_BLOCK))
    data = rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    frame = LZ4Engine(shards=shards).compress(data)
    info = frame_info(frame)
    assert info["shard_count"] == shards
    assert decode_frame_serial(frame) == data
