"""Frame format, vectorized emitter, batched engine, and decoder fast-path.

Covers the PR-1 acceptance surface:
  * LZ4Engine.compress -> decode_frame round-trips bit-exactly on random and
    pathological corpora (empty, all-zeros, incompressible, boundary-straddling);
  * the vectorized emitter is byte-identical to encode_block (the oracle) on
    every block of the property suite;
  * malformed frames are rejected with FrameFormatError;
  * the chunked decoder fast path equals the byte-by-byte oracle, including
    overlapping matches (offset < match_len);
  * the engine issues exactly one device dispatch per micro-batch.
"""
import numpy as np
import pytest

from repro.core import (
    FrameFormatError,
    LZ4Engine,
    Sequence,
    decode_block,
    decode_block_bytewise,
    decode_frame,
    emit_block_from_records,
    encode_block,
    encode_frame,
    frame_info,
)
from repro.core.jax_compressor import (
    compress_block_records,
    pad_block,
    records_to_plan,
)
from repro.core.lz4_types import MAX_BLOCK


def _rng():
    return np.random.default_rng(20260729)


def _property_corpus() -> dict[str, bytes]:
    rng = _rng()
    structured = bytes(rng.integers(0, 16, 64, np.uint8)) * 40
    return {
        "empty": b"",
        "one_byte": b"\x42",
        "zeros_small": b"\x00" * 777,
        "zeros_block": b"\x00" * MAX_BLOCK,
        "incompressible": rng.integers(0, 256, 4096, np.uint8).tobytes(),
        "structured": structured,
        "text": b"the quick brown fox jumps over the lazy dog. " * 300,
        "long_literal_run": (rng.integers(0, 256, 400, np.uint8).tobytes()
                             + b"Q" * 800
                             + rng.integers(0, 256, 400, np.uint8).tobytes()),
        "low_entropy": rng.integers(0, 4, 20000, np.uint8).tobytes(),
        "full_block": rng.integers(0, 16, MAX_BLOCK, np.uint8).tobytes(),
    }


def _records(data: bytes):
    import jax.numpy as jnp

    buf, n = pad_block(data)
    return compress_block_records(jnp.asarray(buf), jnp.int32(n)), n


# ---------------------------------------------------------------------------
# Vectorized emitter == encode_block oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(_property_corpus().keys()))
def test_emitter_bit_identical_to_encode_block(name):
    data = _property_corpus()[name]
    rec, n = _records(data)
    oracle = encode_block(data, records_to_plan(rec, n))
    fast = emit_block_from_records(data, rec, n)
    assert fast == oracle
    assert len(fast) == int(rec.size)
    assert decode_block(fast) == data


def test_emitter_random_lengths():
    rng = _rng()
    for size in (1, 14, 15, 16, 255, 270, 271, 4096):
        data = bytes(rng.integers(0, 8, size, np.uint8))
        rec, n = _records(data)
        assert emit_block_from_records(data, rec, n) == encode_block(
            data, records_to_plan(rec, n)
        )


# ---------------------------------------------------------------------------
# Frame round trips (engine end-to-end)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    return LZ4Engine(micro_batch=4)


@pytest.mark.parametrize("case", [
    "empty", "tiny", "all_zeros_multi", "incompressible_multi",
    "boundary_straddle", "off_by_one",
])
def test_frame_roundtrip(engine, case):
    rng = _rng()
    data = {
        "empty": b"",
        "tiny": b"xyz",
        "all_zeros_multi": b"\x00" * (2 * MAX_BLOCK + 17),
        "incompressible_multi": rng.integers(0, 256, MAX_BLOCK + 5000, np.uint8).tobytes(),
        # A repeated unit straddling the 64 KB boundary: blocks are
        # independent, so the straddling match must NOT survive framing.
        "boundary_straddle": (b"ab" * ((MAX_BLOCK - 7) // 2))[: MAX_BLOCK - 7]
                             + b"pattern-pattern-pattern-" * 1000,
        "off_by_one": b"z" * (MAX_BLOCK + 1),
    }[case]
    frame = engine.compress(data)
    assert engine.decompress(frame) == data
    assert decode_frame(frame) == data
    info = frame_info(frame)
    assert info["block_count"] == -(-len(data) // MAX_BLOCK) if data else info["block_count"] == 0
    assert sum(b["usize"] for b in info["blocks"]) == len(data)


def test_frame_incompressible_uses_passthrough(engine):
    data = _rng().integers(0, 256, MAX_BLOCK, np.uint8).tobytes()
    frame = engine.compress(data)
    info = frame_info(frame)
    assert [b["raw"] for b in info["blocks"]] == [True]
    # Passthrough bounds expansion to the frame header + table (v3 header
    # adds an 8-byte content size; entries are 12 bytes: usize, csize/flag,
    # content crc32).
    assert len(frame) == len(data) + 9 + 8 + 12
    assert decode_frame(frame) == data


def test_frame_roundtrip_random_sizes(engine):
    rng = _rng()
    for size in (MAX_BLOCK - 1, MAX_BLOCK, MAX_BLOCK + 1, 3 * MAX_BLOCK + 4242):
        data = bytes(rng.integers(0, 32, size, np.uint8))
        assert decode_frame(engine.compress(data)) == data


# ---------------------------------------------------------------------------
# Malformed-frame rejection
# ---------------------------------------------------------------------------

def _good_frame(engine=None):
    return (engine or LZ4Engine(micro_batch=1)).compress(b"hello world " * 100)


def test_frame_rejects_bad_magic(engine):
    frame = bytearray(_good_frame(engine))
    frame[:4] = b"NOPE"
    with pytest.raises(FrameFormatError, match="magic"):
        decode_frame(bytes(frame))


def test_frame_rejects_bad_version(engine):
    frame = bytearray(_good_frame(engine))
    frame[4] = 99
    with pytest.raises(FrameFormatError, match="version"):
        decode_frame(bytes(frame))


def test_frame_rejects_truncation(engine):
    frame = _good_frame(engine)
    for cut in (0, 3, 8, 12, len(frame) - 1):
        with pytest.raises(FrameFormatError):
            decode_frame(frame[:cut])


def test_frame_rejects_trailing_garbage(engine):
    with pytest.raises(FrameFormatError):
        decode_frame(_good_frame(engine) + b"\x00")


def test_frame_rejects_lying_usize(engine):
    frame = bytearray(_good_frame(engine))
    # usize field of block 0 lives right after the 17-byte v3 header
    # (9-byte base + 8-byte content size).
    frame[17:21] = (1199).to_bytes(4, "little")
    with pytest.raises(FrameFormatError):
        decode_frame(bytes(frame))


def test_frame_rejects_lying_content_size(engine):
    # The v3 content-size header must match the block table BEFORE any
    # payload is decoded.
    frame = bytearray(_good_frame(engine))
    assert frame[4] == 3
    frame[9:17] = (12345).to_bytes(8, "little")
    with pytest.raises(FrameFormatError, match="content size"):
        frame_info(bytes(frame))


def test_frame_v2_writer_still_available(engine):
    # content_size=False reproduces the pre-v3 writer byte-for-byte shape.
    data = b"versioned " * 50
    from repro.core import block_crc

    frame = encode_frame([data], [len(data)], [True],
                         checksums=[block_crc(data)], content_size=False)
    assert frame[4] == 2
    assert decode_frame(frame) == data
    assert frame_info(frame)["content_size"] is None


def test_frame_rejects_raw_size_mismatch():
    # Hand-build a frame whose raw flag lies about its payload size.
    good = encode_frame([b"abcd"], [4], [True])
    bad = bytearray(good)
    bad[9:13] = (5).to_bytes(4, "little")  # usize=5, csize still 4
    with pytest.raises(FrameFormatError):
        decode_frame(bytes(bad))


def test_encode_frame_validates_inputs():
    with pytest.raises(ValueError):
        encode_frame([b"x"], [1], [True, False])
    with pytest.raises(ValueError):
        encode_frame([b"xy"], [1], [True])  # raw payload != usize
    with pytest.raises(ValueError):
        encode_frame([b""], [MAX_BLOCK + 1], [False])


# ---------------------------------------------------------------------------
# Decoder fast path vs byte-by-byte oracle
# ---------------------------------------------------------------------------

def test_decoder_fastpath_overlapping_matches():
    # offset < match_len forces pattern replication in the chunked path.
    for offset, mlen, lead in [(1, 95, b"a"), (2, 40, b"ab"), (3, 100, b"xyz"),
                               (7, 64, b"restart"), (5, 6, b"olapp")]:
        data = lead + (lead * (mlen // len(lead) + 2))[:mlen]
        plan = [Sequence(0, len(lead), mlen, offset), Sequence(len(lead) + mlen, 0)]
        block = encode_block(data, plan)
        assert decode_block(block) == decode_block_bytewise(block) == data


def test_decoder_fastpath_equals_oracle_on_corpus(engine):
    for name, data in _property_corpus().items():
        rec, n = _records(data)
        block = emit_block_from_records(data, rec, n)
        assert decode_block(block) == decode_block_bytewise(block) == data, name


def test_decoder_fastpath_rejects_same_errors():
    bad = [b"", b"\xf0", b"\x10", b"\x04abcd\x00\x00", b"\x04abcd\xff\xff"]
    for blk in bad:
        with pytest.raises(ValueError):
            decode_block(blk)
        with pytest.raises(ValueError):
            decode_block_bytewise(blk)


# ---------------------------------------------------------------------------
# Engine dispatch batching
# ---------------------------------------------------------------------------

def test_engine_one_dispatch_per_micro_batch(monkeypatch):
    eng = LZ4Engine(micro_batch=2)
    calls = []
    orig = LZ4Engine._dispatch

    def spy(self, stack, ns, st):
        calls.append(stack.shape[0])
        return orig(self, stack, ns, st)

    monkeypatch.setattr(LZ4Engine, "_dispatch", spy)
    data = b"spam and eggs " * 24000  # 5 blocks + change
    frame = eng.compress(data)
    assert decode_frame(frame) == data
    # 6 blocks, micro_batch 2 -> exactly 3 dispatches, each of batch 2.
    assert calls == [2, 2, 2]
    assert eng.stats.dispatches == 3
    assert eng.stats.blocks == 6


def test_engine_pads_partial_batch_to_pow2(monkeypatch):
    eng = LZ4Engine(micro_batch=32)
    shapes = []
    orig = LZ4Engine._dispatch
    monkeypatch.setattr(
        LZ4Engine, "_dispatch",
        lambda self, stack, ns, st:
            shapes.append(stack.shape[0]) or orig(self, stack, ns, st),
    )
    data = b"ham " * 50000  # 200_000 bytes -> 4 blocks
    assert decode_frame(eng.compress(data)) == data
    assert shapes == [4]  # padded to the next power of two, not to 32


# ---------------------------------------------------------------------------
# Frame v4 (sharded container) units.
# ---------------------------------------------------------------------------

class TestFrameV4:
    def _frame(self, shards=(0, 0, 1, 2), shard_count=None):
        from repro.core import block_crc

        payloads = [b"%d" % i * (i + 1) for i in range(len(shards))]
        usizes = [len(p) for p in payloads]
        return encode_frame(
            payloads, usizes, [True] * len(shards),
            checksums=[block_crc(p) for p in payloads],
            shards=list(shards), shard_count=shard_count)

    def test_v4_header_and_table(self):
        frame = self._frame()
        info = frame_info(frame)
        assert info["version"] == 4
        assert info["shard_count"] == 3
        assert [b["shard"] for b in info["blocks"]] == [0, 0, 1, 2]
        assert info["content_size"] == sum(b["usize"] for b in info["blocks"])

    def test_shard_count_defaults_to_max_plus_one(self):
        assert frame_info(self._frame(shards=(0, 1)))["shard_count"] == 2

    def test_trailing_empty_shards_allowed(self):
        info = frame_info(self._frame(shards=(0, 0, 0, 1), shard_count=8))
        assert info["shard_count"] == 8

    def test_pre_v4_blocks_have_no_shard(self):
        v3 = LZ4Engine().compress(b"abc" * 100)
        info = frame_info(v3)
        assert info["shard_count"] is None
        assert all(b["shard"] is None for b in info["blocks"])

    def test_v4_decodes_with_all_readers(self):
        from repro.core import LZ4DecodeEngine, decode_frame_serial

        data = b"reader parity " * 15000  # 4 blocks
        frame = LZ4Engine(shards=2).compress(data)
        assert frame_info(frame)["version"] == 4
        assert decode_frame(frame) == data
        assert decode_frame_serial(frame) == data
        assert decode_frame_serial(frame, bytewise=True) == data
        assert LZ4DecodeEngine(executor="device").decode(frame) == data

    def test_max_version_guard(self):
        frame = self._frame()
        with pytest.raises(FrameFormatError, match="max_version"):
            frame_info(frame, max_version=3)
        assert frame_info(frame, max_version=4)["version"] == 4

    def test_empty_v4(self):
        frame = encode_frame([], [], [], checksums=[], shards=[])
        info = frame_info(frame)
        assert info["version"] == 4 and info["shard_count"] == 1
        assert decode_frame(frame) == b""


# ---------------------------------------------------------------------------
# Frame v5 (whole-content checksum trailer) units.
# ---------------------------------------------------------------------------

class TestFrameV5:
    def _data(self):
        return b"whole-object trailer " * 9000  # 3 blocks

    def test_v5_header_trailer_and_shard_column(self):
        from repro.core import VERSION_V5, block_crc

        data = self._data()
        frame = LZ4Engine(content_crc=True).compress(data)
        info = frame_info(frame)
        assert info["version"] == VERSION_V5
        assert info["content_crc"] == block_crc(data)
        # Unsharded v5 records a degenerate shard column: one shard, all 0.
        assert info["shard_count"] == 1
        assert all(b["shard"] == 0 for b in info["blocks"])

    def test_pre_v5_frames_have_no_content_crc(self):
        for eng in (LZ4Engine(), LZ4Engine(shards=2)):
            assert frame_info(eng.compress(self._data()))["content_crc"] is None

    def test_v5_decodes_with_all_readers(self):
        from repro.core import LZ4DecodeEngine, decode_frame_serial

        data = self._data()
        frame = LZ4Engine(content_crc=True).compress(data)
        assert decode_frame(frame) == data
        assert decode_frame_serial(frame) == data
        assert decode_frame_serial(frame, bytewise=True) == data
        eng = LZ4DecodeEngine(executor="device")
        assert eng.decode(frame) == data
        out = eng.decode_to_device(frame)
        assert bytes(np.asarray(out)) == data
        assert eng.stats.host_bytes == 0  # trailer check stays in-graph

    def test_v5_sharded(self):
        from repro.core import VERSION_V5, block_crc, decode_frame_serial

        data = self._data()
        frame = LZ4Engine(shards=3, content_crc=True).compress(data)
        info = frame_info(frame)
        assert info["version"] == VERSION_V5
        assert info["shard_count"] == 3
        assert info["content_crc"] == block_crc(data)
        assert decode_frame(frame) == data
        assert decode_frame_serial(frame) == data

    def test_v5_trailer_mismatch_rejected_by_full_decoders(self):
        from repro.core import LZ4DecodeEngine, decode_frame_serial

        data = self._data()
        frame = LZ4Engine(content_crc=True).compress(data)
        bad = frame[:-4] + bytes(b ^ 0xFF for b in frame[-4:])
        eng = LZ4DecodeEngine(executor="device")
        for decode in (decode_frame, decode_frame_serial, eng.decode,
                       eng.decode_to_device):
            with pytest.raises(FrameFormatError,
                               match="content checksum mismatch"):
                decode(bad)
        # verify=False skips the trailer (and per-block) verification.
        out = eng.decode_to_device(bad, verify=False)
        assert bytes(np.asarray(out)) == data

    def test_v5_partial_reads_skip_trailer(self):
        from repro.core import FrameReader

        data = self._data()
        frame = LZ4Engine(content_crc=True).compress(data)
        bad = frame[:-4] + bytes(b ^ 0xFF for b in frame[-4:])
        # Partial reads never materialise the whole object, so the lying
        # trailer is invisible to them — per-block CRCs still protect them.
        assert FrameReader(bad).read_range(70000, 100) == data[70000:70100]

    def test_v5_truncated_trailer_rejected(self):
        frame = LZ4Engine(content_crc=True).compress(self._data())
        with pytest.raises(FrameFormatError, match="frame length"):
            frame_info(frame[:-2])

    def test_v4_reader_rejects_v5(self):
        frame = LZ4Engine(content_crc=True).compress(b"x" * 100)
        with pytest.raises(FrameFormatError, match="max_version"):
            frame_info(frame, max_version=4)

    def test_v5_encode_validation(self):
        with pytest.raises(ValueError, match="version-5"):
            encode_frame([b"a"], [1], [True], content_crc=1)
        with pytest.raises(ValueError, match="version-5"):
            encode_frame([b"a"], [1], [True], checksums=[0],
                         content_size=False, content_crc=1)

    def test_empty_v5(self):
        import binascii

        frame = encode_frame([], [], [], checksums=[],
                             content_crc=binascii.crc32(b""))
        info = frame_info(frame)
        assert info["version"] == 5 and info["content_crc"] == 0
        assert decode_frame(frame) == b""


# ---------------------------------------------------------------------------
# Frame v6 (XOR parity groups) units.
# ---------------------------------------------------------------------------

class TestFrameV6:
    def _data(self):
        rng = _rng()
        # Compressible + incompressible mix: parity must cover both LZ4 and
        # raw-passthrough stored payloads.
        return (b"parity-protected frame " * 6000
                + rng.integers(0, 256, 70000, np.uint8).tobytes())

    def test_v6_header_parity_table_and_trailer(self):
        from repro.core import VERSION_V6, block_crc

        data = self._data()
        frame = LZ4Engine(parity_group=2).compress(data)
        info = frame_info(frame)
        assert info["version"] == VERSION_V6
        assert info["parity_group"] == 2
        n_groups = -(-info["block_count"] // 2)
        assert len(info["parity"]) == n_groups
        # v6 always carries the whole-content trailer (implied content_crc).
        assert info["content_crc"] == block_crc(data)
        for g, p in enumerate(info["parity"]):
            grp = info["blocks"][g * 2: (g + 1) * 2]
            assert p["plen"] == max(b["csize"] for b in grp)
            payload = frame[p["offset"]: p["offset"] + p["plen"]]
            assert block_crc(payload) == p["crc"]

    def test_parity_is_xor_of_stored_payloads(self):
        from repro.core import xor_bytes

        data = self._data()
        frame = LZ4Engine(parity_group=3).compress(data)
        info = frame_info(frame)
        for g, p in enumerate(info["parity"]):
            grp = info["blocks"][g * 3: (g + 1) * 3]
            stored = [frame[b["offset"]: b["offset"] + b["csize"]]
                      for b in grp]
            assert frame[p["offset"]: p["offset"] + p["plen"]] == \
                xor_bytes(stored, p["plen"])

    def test_v6_decodes_with_all_readers(self):
        from repro.core import LZ4DecodeEngine, decode_frame_serial

        data = self._data()
        frame = LZ4Engine(parity_group=4).compress(data)
        assert decode_frame(frame) == data
        assert decode_frame_serial(frame) == data
        assert decode_frame_serial(frame, bytewise=True) == data
        eng = LZ4DecodeEngine(executor="device")
        assert eng.decode(frame) == data
        assert bytes(np.asarray(eng.decode_to_device(frame))) == data

    def test_v6_partial_reads_skip_parity(self):
        from repro.core import FrameReader

        data = self._data()
        frame = LZ4Engine(parity_group=2).compress(data)
        # Damage the PARITY payload only: partial and full reads never
        # touch it, so both still succeed.
        info = frame_info(frame)
        p = info["parity"][0]
        bad = bytearray(frame)
        bad[p["offset"]] ^= 0xFF
        bad = bytes(bad)
        assert FrameReader(bad).read_range(70000, 100) == data[70000:70100]
        assert decode_frame(bad) == data

    def test_parity_off_is_byte_identical(self):
        data = self._data()
        assert LZ4Engine().compress(data) == \
            LZ4Engine(parity_group=None).compress(data)

    def test_v6_sharded(self):
        from repro.core import VERSION_V6, decode_frame_serial

        data = self._data()
        frame = LZ4Engine(shards=3, parity_group=2).compress(data)
        info = frame_info(frame)
        assert info["version"] == VERSION_V6
        assert info["shard_count"] == 3
        assert decode_frame(frame) == data
        assert decode_frame_serial(frame) == data

    def test_v5_reader_rejects_v6(self):
        frame = LZ4Engine(parity_group=1).compress(b"x" * 100)
        with pytest.raises(FrameFormatError, match="max_version"):
            frame_info(frame, max_version=5)

    def test_v6_lying_plen_rejected(self):
        frame = LZ4Engine(parity_group=2).compress(self._data())
        info = frame_info(frame)
        # Corrupt the first parity-table entry's plen field.
        ptable_off = info["parity"][0]["offset"] - \
            len(info["parity"]) * 8
        bad = bytearray(frame)
        bad[ptable_off] ^= 0x01
        with pytest.raises(FrameFormatError, match="plen"):
            frame_info(bytes(bad))

    def test_v6_truncated_parity_rejected(self):
        frame = LZ4Engine(parity_group=2).compress(self._data())
        info = frame_info(frame)
        cut = info["parity"][0]["offset"] - 2
        with pytest.raises(FrameFormatError, match="truncated parity table"):
            frame_info(frame[:cut])

    def test_v6_encode_validation(self):
        with pytest.raises(ValueError, match="content_crc"):
            encode_frame([b"a"], [1], [True], checksums=[0],
                         parity_group=2)
        with pytest.raises(ValueError, match="parity_group"):
            LZ4Engine(parity_group=0)

    def test_empty_v6(self):
        import binascii

        frame = encode_frame([], [], [], checksums=[],
                             content_crc=binascii.crc32(b""),
                             parity_group=4)
        info = frame_info(frame)
        assert info["version"] == 6 and info["parity"] == []
        assert decode_frame(frame) == b""
