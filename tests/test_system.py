"""End-to-end system tests: training convergence, failure recovery,
gradient-compression training, example entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod


def _args(tmp_path, **kw):
    defaults = dict(
        arch="internlm2-1.8b", scale="tiny", steps=30, batch=4, seq=64,
        lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100,
        resume=True, async_ckpt=False, grad_compress=False,
        simulate_failure=None, seed=0,
    )
    defaults.update(kw)
    return type("Args", (), defaults)()


def test_training_loss_decreases(tmp_path):
    out = train_mod.train(_args(tmp_path))
    losses = out["losses"]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert np.isfinite(last)
    assert last < first * 0.98, (first, last)


def test_failure_recovery_resumes_and_finishes(tmp_path):
    out = train_mod.train(_args(tmp_path, steps=24, simulate_failure=15, ckpt_every=6))
    assert len(out["losses"]) >= 24
    assert np.isfinite(out["final_loss"])


def test_recovery_matches_uninterrupted_run(tmp_path):
    """Bitwise-deterministic pipeline: a crash+restore run must end at the
    same loss as an uninterrupted run (checkpoint captures full state)."""
    a = train_mod.train(_args(tmp_path / "a", steps=20, ckpt_every=5))
    b = train_mod.train(
        _args(tmp_path / "b", steps=20, ckpt_every=5, simulate_failure=10)
    )
    # batches are a pure function of step; state restored from step 10
    np.testing.assert_allclose(a["final_loss"], b["final_loss"], rtol=1e-4)


def test_grad_compressed_training_converges(tmp_path):
    base = train_mod.train(_args(tmp_path / "fp", steps=25, lr=1e-3))
    comp = train_mod.train(_args(tmp_path / "q8", steps=25, lr=1e-3, grad_compress=True))
    # int8+EF tracks fp32 closely on this scale
    assert abs(comp["final_loss"] - base["final_loss"]) < 0.15 * base["final_loss"]


def test_wsd_schedule_selected_for_minicpm(tmp_path):
    out = train_mod.train(_args(tmp_path, arch="minicpm-2b", steps=8, batch=2, seq=32))
    assert np.isfinite(out["final_loss"])
