"""Parallel decompression subsystem (PR-2 acceptance surface).

  * plan/execute decode (`decode_block_planned`) is bit-identical to both
    serial oracles on random, structured, and overlap-heavy adversarial
    blocks — including blocks engineered to exercise the vectorized wave
    path and the sequential fallback;
  * `LZ4DecodeEngine.decode` equals `decode_frame_serial` (and the original
    input) on the full corpus, at 1 and 4 workers, including raw-passthrough
    blocks;
  * `FrameReader.read_range(start, length)` equals `original[start:start+length]`
    for randomized and boundary ranges, decoding only the covering blocks;
  * the decoder `max_out` cap is enforced BEFORE literal appends and match
    copies (a lying length field can no longer overshoot the cap);
  * version-2 frames detect content corruption via per-block CRC32.
"""
import numpy as np
import pytest

from repro.core import (
    FrameFormatError,
    FrameReader,
    LZ4DecodeEngine,
    LZ4Engine,
    LZ4FormatError,
    Sequence,
    decode_block,
    decode_block_bytewise,
    decode_block_planned,
    decode_frame,
    decode_frame_serial,
    encode_block,
    encode_frame,
    execute_plan,
    plan_block,
)
from repro.core.lz4_types import MAX_BLOCK


def _rng():
    return np.random.default_rng(20260730)


@pytest.fixture(scope="module")
def engine():
    return LZ4Engine(micro_batch=4)


def _block_corpus() -> dict[str, bytes]:
    rng = _rng()
    return {
        "empty": b"",
        "one": b"\x51",
        "text": b"the quick brown fox jumps over the lazy dog. " * 400,
        "zeros": b"\x00" * MAX_BLOCK,
        "low_entropy": rng.integers(0, 4, 30000, np.uint8).tobytes(),
        "incompressible": rng.integers(0, 256, 4096, np.uint8).tobytes(),
        "structured": bytes(rng.integers(0, 16, 64, np.uint8)) * 40,
        "literal_tail": rng.integers(0, 256, 700, np.uint8).tobytes()
                        + b"Q" * 900
                        + rng.integers(0, 256, 300, np.uint8).tobytes(),
    }


def _frame_corpus(engine) -> dict[str, bytes]:
    rng = _rng()
    return {
        "empty": b"",
        "tiny": b"xyz",
        "multi_text": b"spam and eggs and ham, " * 12000,
        "zeros_multi": b"\x00" * (2 * MAX_BLOCK + 17),
        "raw_multi": rng.integers(0, 256, MAX_BLOCK + 5000, np.uint8).tobytes(),
        "mixed": ((b"ab" * MAX_BLOCK)[:MAX_BLOCK - 7]
                  + rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes()
                  + b"pattern-" * 4000),
    }


def _encode_oracle(data: bytes) -> bytes:
    from repro.core import compress_windowed

    res = compress_windowed(data, hash_bits=8, max_match=36)
    return encode_block(data, res.sequences)


# ---------------------------------------------------------------------------
# plan/execute vs serial oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(_block_corpus().keys()))
def test_planned_decode_equals_oracles(name):
    data = _block_corpus()[name]
    blk = _encode_oracle(data)
    assert decode_block_planned(blk) == decode_block(blk) \
        == decode_block_bytewise(blk) == data


def test_planned_decode_overlap_heavy():
    # offset < match_len forces pattern replication; chains of such matches
    # force the wave scheduler into its sequential fallback.
    for offset, mlen, lead in [(1, 95, b"a"), (2, 40, b"ab"), (3, 100, b"xyz"),
                               (7, 64, b"restart"), (5, 6, b"olapp"),
                               (1, 5000, b"z"), (2, 2000, b"pq")]:
        data = lead + (lead * (mlen // len(lead) + 2))[:mlen]
        plan = [Sequence(0, len(lead), mlen, offset), Sequence(len(lead) + mlen, 0)]
        blk = encode_block(data, plan)
        assert decode_block_planned(blk) == decode_block_bytewise(blk) == data


def test_planned_decode_random_plans():
    # Adversarial random sequences built directly (not via a compressor):
    # random mixtures of literals and (frequently overlapping) matches,
    # with the ground truth materialized by the bytewise replication rule.
    rng = _rng()
    for trial in range(25):
        src = bytes(rng.integers(0, 256, 4096, np.uint8))
        data = bytearray()
        plan = []
        cursor = 0
        for _ in range(int(rng.integers(1, 40))):
            lit = int(rng.integers(0, 30))
            lit_start = len(data)
            data += src[cursor:cursor + lit]
            cursor += lit
            if len(data) == 0:
                continue  # nothing consumed, nothing to record
            offset = int(rng.integers(1, min(len(data), 65535) + 1))
            mlen = int(rng.integers(4, 60))
            plan.append(Sequence(lit_start, lit, mlen, offset))
            s = len(data) - offset
            for j in range(mlen):
                data.append(data[s + j])
        plan.append(Sequence(len(data), 0))
        data = bytes(data)
        blk = encode_block(data, plan)
        assert decode_block_planned(blk) == decode_block_bytewise(blk) == data, trial


def test_execute_plan_wave_path_many_independent_matches():
    # A long literal prefix followed by many matches that all source far
    # enough back to be ready in early waves -> vectorized gather path.
    rng = _rng()
    prefix = rng.integers(0, 256, 600, np.uint8).tobytes()
    data = bytearray(prefix)
    plan = [Sequence(0, len(prefix), 16, 300)]
    s = len(data) - 300
    data += bytes(data[s:s + 16])
    for k in range(150):
        off = 200 + (k * 3) % 300
        plan.append(Sequence(len(data), 0, 12, off))
        s = len(data) - off
        data += bytes(data[s:s + 12])
    plan.append(Sequence(len(data), 0))
    data = bytes(data)
    blk = encode_block(data, plan)
    assert decode_block_planned(blk) == decode_block_bytewise(blk) == data


def test_execute_plan_into_view():
    data = b"abcabcabc" * 100
    blk = _encode_oracle(data)
    plan = plan_block(blk)
    buf = np.zeros(plan.usize + 10, np.uint8)
    execute_plan(blk, plan, out=buf[5:5 + plan.usize])
    assert buf[5:5 + plan.usize].tobytes() == data
    assert not buf[:5].any() and not buf[-5:].any()
    with pytest.raises(ValueError, match="out buffer"):
        execute_plan(blk, plan, out=buf)


def test_plan_block_rejects_same_errors():
    bad = [b"", b"\xf0", b"\x10", b"\x04abcd\x00\x00", b"\x04abcd\xff\xff"]
    for blk in bad:
        with pytest.raises(LZ4FormatError):
            plan_block(blk)


# ---------------------------------------------------------------------------
# max_out cap enforced before copies (satellite bugfix)
# ---------------------------------------------------------------------------

def _huge_match_block() -> bytes:
    # 1 literal, then a match claiming ~300 KB via extension bytes, then the
    # mandatory final literals-only sequence (empty).
    ext = b"\xff" * 1200 + b"\x10"   # match_len = 19 + 255*1200 + 16
    return b"\x1fa" + b"\x01\x00" + ext + b"\x00"


def _literal_tail_block(n: int) -> bytes:
    # Final literals-only sequence of n bytes (n >= 15).
    ext_val = n - 15
    ext = []
    while True:
        ext.append(min(ext_val, 255))
        if ext[-1] < 255:
            break
        ext_val -= 255
    return bytes([0xF0] + ext) + b"L" * n


@pytest.mark.parametrize("decoder", [decode_block, decode_block_bytewise,
                                     decode_block_planned])
def test_max_out_enforced_before_match_copy(decoder):
    blk = _huge_match_block()
    with pytest.raises(LZ4FormatError, match="exceeds"):
        decoder(blk, max_out=64)
    # Sanity: without a cap the block is valid and huge.
    assert len(decoder(blk)) == 1 + 19 + 255 * 1200 + 16


@pytest.mark.parametrize("decoder", [decode_block, decode_block_bytewise,
                                     decode_block_planned])
def test_max_out_enforced_on_final_literals(decoder):
    # Pre-fix, the final literals-only sequence skipped the cap entirely.
    blk = _literal_tail_block(1000)
    assert decoder(blk) == b"L" * 1000
    with pytest.raises(LZ4FormatError, match="exceeds"):
        decoder(blk, max_out=999)
    assert decoder(blk, max_out=1000) == b"L" * 1000


# ---------------------------------------------------------------------------
# Engine vs serial oracle on frames
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workers,two_phase", [(1, False), (1, True), (4, None)])
def test_engine_decode_bit_identical(engine, workers, two_phase):
    de = LZ4DecodeEngine(workers=workers, two_phase=two_phase)
    for name, data in _frame_corpus(engine).items():
        frame = engine.compress(data)
        got = de.decode(frame)
        assert got == data, name
        assert got == decode_frame_serial(frame), name
        assert got == decode_frame_serial(frame, bytewise=True), name
    de.close()


def test_planner_fast_equals_reference(engine):
    # The vectorized planner must produce byte-identical plans to the
    # serial-parse reference on every compressible corpus block.
    from repro.core import frame_info, plan_block_fast

    for name, data in _frame_corpus(engine).items():
        frame = engine.compress(data)
        info = frame_info(frame)
        for b in info["blocks"]:
            if b["raw"]:
                continue
            payload = frame[b["offset"]: b["offset"] + b["csize"]]
            ref, fast = plan_block(payload), plan_block_fast(payload)
            assert ref.usize == fast.usize, name
            for f in ("lit_src", "lit_dst", "lit_len",
                      "match_dst", "match_src", "match_len"):
                assert np.array_equal(getattr(ref, f), getattr(fast, f)), (name, f)


def test_planner_fast_rejects_what_reference_rejects():
    # Malformed-block parity: on mutated payloads both planners must agree
    # on accept/reject (and on the resulting plan when both accept).
    from repro.core import plan_block_fast

    rng = _rng()
    base = _encode_oracle(b"planner parity " * 800)
    for trial in range(300):
        mutant = bytearray(base)
        pos = int(rng.integers(0, len(base)))
        mutant[pos] = int(rng.integers(0, 256))
        mutant = bytes(mutant)
        try:
            ref = plan_block(mutant)
            ref_err = None
        except LZ4FormatError as e:
            ref, ref_err = None, str(e)
        try:
            fast = plan_block_fast(mutant)
            fast_err = None
        except LZ4FormatError as e:
            fast, fast_err = None, str(e)
        assert (ref is None) == (fast is None), (trial, pos, ref_err, fast_err)
        if ref is not None:
            assert ref.usize == fast.usize, (trial, pos)
        else:
            assert ref_err == fast_err, (trial, pos)
        # And with a cap, exercising the pre-copy limit checks.
        try:
            ref_c = plan_block(mutant, max_out=1000)
            ref_c_err = None
        except LZ4FormatError as e:
            ref_c, ref_c_err = None, str(e)
        try:
            fast_c = plan_block_fast(mutant, max_out=1000)
            fast_c_err = None
        except LZ4FormatError as e:
            fast_c, fast_c_err = None, str(e)
        assert (ref_c is None) == (fast_c is None), (trial, pos)
        if ref_c is None:
            assert ref_c_err == fast_c_err, (trial, pos)


@pytest.mark.parametrize("executor", ["thread", "process"])
def test_engine_executors_bit_identical(engine, executor):
    if executor == "process":
        import multiprocessing as mp

        if "fork" not in mp.get_all_start_methods():
            pytest.skip("fork start method unavailable")
    data = b"executor parity " * 30000  # multi-block
    frame = engine.compress(data)
    with LZ4DecodeEngine(workers=2, executor=executor) as de:
        assert de.decode(frame) == data
        assert de.stats.parallel
        # Corruption must also surface identically through the pool.
        mutant = bytearray(frame)
        mutant[-3] ^= 0x20
        with pytest.raises(FrameFormatError):
            de.decode(bytes(mutant))


def test_engine_decode_parallel_stats(engine):
    data = b"stats check " * 30000  # 6 blocks
    frame = engine.compress(data)
    de = LZ4DecodeEngine(workers=4)
    assert de.decode(frame) == data
    assert de.stats.blocks == 6
    assert de.stats.bytes_out == len(data)
    assert de.stats.parallel
    de.close()


def test_engine_decode_blocks_mixed_raw():
    rng = _rng()
    chunks = [b"ham and jam " * 700, rng.integers(0, 256, 5000, np.uint8).tobytes()]
    payloads = [_encode_oracle(chunks[0]), chunks[1]]
    de = LZ4DecodeEngine(workers=2)
    out = de.decode_blocks(payloads, raws=[False, True],
                           usizes=[len(chunks[0]), len(chunks[1])])
    assert out == chunks
    with pytest.raises(LZ4FormatError):
        de.decode_blocks([payloads[0]], raws=[False], usizes=[len(chunks[0]) - 1])
    de.close()


def test_decode_frame_delegates_to_engine(engine, monkeypatch):
    from repro.core import decode_engine as de_mod

    calls = []
    orig = de_mod.LZ4DecodeEngine.decode

    def spy(self, frame):
        calls.append(len(frame))
        return orig(self, frame)

    monkeypatch.setattr(de_mod.LZ4DecodeEngine, "decode", spy)
    data = b"delegation " * 1000
    frame = engine.compress(data)
    assert decode_frame(frame) == data
    assert calls == [len(frame)]


# ---------------------------------------------------------------------------
# FrameReader random access
# ---------------------------------------------------------------------------

def test_read_range_randomized(engine):
    rng = _rng()
    for name, data in _frame_corpus(engine).items():
        if not data:
            continue
        reader = FrameReader(engine.compress(data))
        assert len(reader) == len(data)
        for _ in range(40):
            start = int(rng.integers(0, len(data)))
            length = int(rng.integers(0, len(data) - start + 1))
            assert reader.read_range(start, length) == data[start:start + length], \
                (name, start, length)


def test_read_range_boundaries(engine):
    data = b"edge case " * 20000  # ~200 KB, 4 blocks
    frame = engine.compress(data)
    reader = FrameReader(frame)
    n = len(data)
    for start, length in [(0, 0), (0, 1), (0, n), (n, 0), (n - 1, 1),
                          (MAX_BLOCK - 1, 2), (MAX_BLOCK, 1),
                          (MAX_BLOCK - 1, MAX_BLOCK + 2),
                          (2 * MAX_BLOCK - 5, 10)]:
        assert reader.read_range(start, length) == data[start:start + length], \
            (start, length)
    for start, length in [(-1, 5), (0, n + 1), (n, 1), (5, -1)]:
        with pytest.raises(ValueError):
            reader.read_range(start, length)


def test_read_range_decodes_only_covering_blocks(engine, monkeypatch):
    data = b"only the needed blocks " * 12000  # ~276 KB -> 5 blocks
    frame = engine.compress(data)
    reader = FrameReader(frame, cache_blocks=0,
                         engine=LZ4DecodeEngine(two_phase=True))
    from repro.core import decode_plan as dp_mod

    planned = []
    orig = dp_mod.plan_block_fast

    def spy(block, max_out=None):
        planned.append(len(block))
        return orig(block, max_out=max_out)

    monkeypatch.setattr("repro.core.decode_engine.plan_block_fast", spy)
    # A range inside block 1 must plan exactly one block.
    reader.read_range(MAX_BLOCK + 100, 500)
    assert len(planned) == 1
    planned.clear()
    # A range straddling blocks 1-2 must plan exactly two.
    reader.read_range(2 * MAX_BLOCK - 50, 100)
    assert len(planned) == 2
    # With the LRU on, a repeated clustered read decodes nothing, and a
    # shifted overlapping read decodes only the one missing block.
    cached = FrameReader(frame, cache_blocks=4,
                         engine=LZ4DecodeEngine(two_phase=True))
    planned.clear()
    assert cached.read_range(2 * MAX_BLOCK - 50, 100) == \
        data[2 * MAX_BLOCK - 50: 2 * MAX_BLOCK + 50]
    assert len(planned) == 2
    planned.clear()
    cached.read_range(2 * MAX_BLOCK - 50, 100)
    assert len(planned) == 0  # both covering blocks reused from the LRU
    cached.read_range(3 * MAX_BLOCK - 50, 100)  # blocks 2 (cached) + 3
    assert len(planned) == 1


def test_read_range_zero_length_everywhere(engine):
    # Zero-length reads are valid at EVERY position 0..usize inclusive —
    # including exactly at EOF — and must decode no blocks at all.
    data = b"zero length " * 17000  # 3+ blocks
    frame = engine.compress(data)
    reader = FrameReader(frame, cache_blocks=0)
    for start in (0, 1, MAX_BLOCK - 1, MAX_BLOCK, MAX_BLOCK + 1,
                  len(data) - 1, len(data)):
        assert reader.read_range(start, 0) == b""
        assert reader.blocks_for_range(start, 0) == range(0, 0)
    # The empty frame supports exactly the (0, 0) read.
    empty = FrameReader(engine.compress(b""))
    assert empty.usize == 0 and empty.read_range(0, 0) == b""
    with pytest.raises(ValueError):
        empty.read_range(0, 1)


def test_read_range_past_eof_rejected(engine):
    data = b"eof bounds " * 9000
    reader = FrameReader(engine.compress(data))
    n = len(data)
    for start, length in [(n + 1, 0), (n, 1), (n - 1, 2), (0, n + 1),
                          (n + 100, 5), (2 * n, 0)]:
        with pytest.raises(ValueError, match="outside"):
            reader.read_range(start, length)
    # Bounds must hold for the seek index itself too.
    with pytest.raises(ValueError):
        reader.blocks_for_range(n, 1)


def test_read_range_exact_block_boundaries(engine):
    # Reads landing exactly on 64 KB block boundaries: a full single block
    # must decode exactly that block, an exact multi-block span exactly
    # those blocks, never a neighbour.
    data = b"B" * (3 * MAX_BLOCK)  # 3 exact blocks, no partial tail
    frame = engine.compress(data)
    reader = FrameReader(frame, cache_blocks=0)
    assert reader.block_count == 3
    for i in range(3):
        a, b = reader.block_range(i)
        assert (a, b) == (i * MAX_BLOCK, (i + 1) * MAX_BLOCK)
        assert reader.blocks_for_range(a, MAX_BLOCK) == range(i, i + 1)
        assert reader.read_range(a, MAX_BLOCK) == data[a:b]
    # Exact two-block span; and the one-byte-each straddle around an edge.
    assert reader.blocks_for_range(MAX_BLOCK, 2 * MAX_BLOCK) == range(1, 3)
    assert reader.read_range(MAX_BLOCK, 2 * MAX_BLOCK) == data[MAX_BLOCK:]
    assert reader.blocks_for_range(MAX_BLOCK - 1, 2) == range(0, 2)
    assert reader.read_range(MAX_BLOCK - 1, 2) == data[MAX_BLOCK - 1: MAX_BLOCK + 1]
    # First byte of a block belongs to that block alone.
    assert reader.blocks_for_range(2 * MAX_BLOCK, 1) == range(2, 3)


def test_read_block_and_cache(engine):
    data = b"cached block reads " * 15000
    frame = engine.compress(data)
    reader = FrameReader(frame, cache_blocks=2)
    for i in range(reader.block_count):
        a, b = reader.block_range(i)
        blk = reader.read_block(i)
        assert blk == data[a:b]
        assert reader.read_block(i) == blk  # cached hit
    with pytest.raises(IndexError):
        reader.read_block(reader.block_count)
    with pytest.raises(IndexError):
        reader.read_block(-1)


def test_reader_usize_without_decode(engine):
    data = b"\x00" * (3 * MAX_BLOCK + 99)
    reader = FrameReader(engine.compress(data))
    assert reader.usize == len(data)
    assert reader.blocks_for_range(0, len(data)) == range(0, 4)
    assert list(reader.blocks_for_range(MAX_BLOCK, 1)) == [1]


# ---------------------------------------------------------------------------
# Checksummed frames (v2) detect corruption
# ---------------------------------------------------------------------------

def test_v2_checksum_detects_payload_corruption(engine):
    data = b"integrity matters " * 9000
    frame = bytearray(engine.compress(data))
    # Flip one bit deep in the last payload (valid token stream bytes may
    # still parse — only the checksum can catch this class of corruption).
    frame[-7] ^= 0x40
    for fn in (decode_frame, decode_frame_serial):
        with pytest.raises(FrameFormatError):
            fn(bytes(frame))


def test_v1_frames_still_decode():
    payload = b"legacy bytes"
    frame = encode_frame([payload], [len(payload)], [True])
    assert frame[4] == 1  # version byte
    assert decode_frame(frame) == payload
    assert decode_frame_serial(frame) == payload
    assert FrameReader(frame).read_range(2, 5) == payload[2:7]


def test_v2_raw_block_checksummed():
    from repro.core import block_crc

    payload = b"raw but protected"
    frame = bytearray(encode_frame([payload], [len(payload)], [True],
                                   checksums=[block_crc(payload)],
                                   content_size=False))
    assert frame[4] == 2
    assert decode_frame(bytes(frame)) == payload
    frame[-1] ^= 0x01
    with pytest.raises(FrameFormatError, match="checksum"):
        decode_frame(bytes(frame))
