"""Fused single-pass compression datapath (PR-5 acceptance surface).

  * `candidate_impl="fused"` (ONE kernel for hash -> LVT candidate -> word
    compare -> bounded extension, kernels/fused_compress.py) produces match
    records bit-identical to the staged `"sort"` oracle on random and
    adversarial corpora — RLE runs, extension-byte boundaries,
    incompressible noise, all-zero blocks, tile-straddling matches — and
    frames byte-identical through the engine;
  * the interpret-mode Pallas kernel equals the jnp twin (`ref.fused_ref`)
    ELEMENTWISE (cand and lengths, not just records), and both equal the
    staged `_candidates` + `match_lengths` oracle chain;
  * the sweep holds across (hash_bits, max_match, pws) corners;
  * a seed-construction guard (like test_device_emit.py): fused/auto
    engine frames must equal the frame built by hand from the sort-path
    records + host emitter + encode_frame;
  * `candidate_impl="auto"` resolves per backend (sortkey on CPU — the
    measured CPU ranking, see BENCH_engine_batched.json; scatter on
    GPU/TPU-without-Pallas, fused on TPU with use_pallas — the expected
    accelerator shapes), rejects unknown names, and the RESOLVED choice
    lands in `EngineStats.candidate_impl`;
  * `kernels.ops.crc32_bytes` (in-graph slice-by-8 CRC-32, the device-side
    verify satellite) equals `binascii.crc32` across length corners.
"""
import binascii

import numpy as np
import pytest

from repro.core import (
    CANDIDATE_IMPLS,
    LZ4Engine,
    decode_frame,
    encode_frame,
    resolve_candidate_impl,
)
from repro.core.emitter import emit_block
from repro.core.frame import block_crc
from repro.core.jax_compressor import (
    _candidates,
    compress_block_bytes,
    compress_block_records,
    pad_block,
)
from repro.core.lz4_types import MAX_BLOCK, MF_LIMIT, MIN_MATCH
from repro.kernels import ops
from repro.kernels.fused_compress import TILE


def _rng():
    return np.random.default_rng(20260729)


def _adversarial_corpus() -> dict[str, bytes]:
    """Blocks aimed at the fused datapath's edge cases: RLE chains, token
    nibble / extension-byte boundaries, incompressible noise, and matches
    whose candidates live in earlier kernel tiles."""
    rng = _rng()
    seed64 = bytes(rng.integers(0, 16, 64, np.uint8))
    return {
        "empty": b"",
        "one_byte": b"\x07",
        "all_zero_block": b"\x00" * MAX_BLOCK,
        "all_zero_short": b"\x00" * 1000,
        "incompressible": rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes(),
        "incompressible_short": rng.integers(0, 256, 4096, np.uint8).tobytes(),
        "rle_runs": b"\xaa" * 13 + b"\xbb" * 300 + b"\xaa" * 5000,
        "rle_to_boundary": b"\xcd" * MAX_BLOCK,
        "lit_nibble_edge": bytes(rng.integers(0, 256, 14, np.uint8)) + b"Z" * 64,
        "lit_ext_edge": bytes(rng.integers(0, 256, 269, np.uint8)) + b"Z" * 64,
        "lit_ext_edge2": bytes(rng.integers(0, 256, 270, np.uint8)) + b"Z" * 64,
        "text": b"the quick brown fox jumps over the lazy dog. " * 1000,
        "low_entropy": rng.integers(0, 4, MAX_BLOCK, np.uint8).tobytes(),
        # Candidates always in earlier tiles: the 64-byte seed repeats
        # across all 32 position tiles, so cross-tile LVT reads dominate.
        "structured": seed64 * (MAX_BLOCK // 64),
        # A long match STRADDLING a tile boundary, whose candidate sits
        # right before the previous boundary: exercises both the in-tile
        # exclusive cummax and the persistent-table handoff at TILE.
        "tile_straddle": (bytes(rng.integers(0, 256, TILE - 30, np.uint8))
                          + seed64 + bytes(rng.integers(0, 256, TILE - 80,
                                                        np.uint8)) + seed64),
    }


def _records(data: bytes, impl: str, use_pallas: bool = False, **kw):
    import jax.numpy as jnp

    buf, n = pad_block(data)
    return compress_block_records(jnp.asarray(buf), jnp.int32(n),
                                  candidate_impl=impl,
                                  use_pallas=use_pallas, **kw)


def _assert_records_equal(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.emit), np.asarray(b.emit), msg)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos), msg)
    np.testing.assert_array_equal(np.asarray(a.length), np.asarray(b.length), msg)
    np.testing.assert_array_equal(np.asarray(a.offset), np.asarray(b.offset), msg)
    assert int(a.size) == int(b.size), msg


# ---------------------------------------------------------------------------
# Record-level bit-identity vs the sort oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(_adversarial_corpus().keys()))
def test_fused_records_equal_sort_oracle(name):
    data = _adversarial_corpus()[name]
    _assert_records_equal(_records(data, "sort"), _records(data, "fused"), name)


@pytest.mark.parametrize("hash_bits,max_match,pws",
                         [(6, 12, 8), (10, 68, 4), (8, 36, 16), (12, 36, 8)])
def test_fused_param_sweep(hash_bits, max_match, pws):
    for name in ("text", "low_entropy", "all_zero_short", "tile_straddle"):
        data = _adversarial_corpus()[name]
        kw = dict(hash_bits=hash_bits, max_match=max_match, pws=pws)
        _assert_records_equal(_records(data, "sort", **kw),
                              _records(data, "fused", **kw),
                              (name, hash_bits, max_match, pws))


# ---------------------------------------------------------------------------
# Kernel == jnp twin == staged oracle chain, ELEMENTWISE
# ---------------------------------------------------------------------------

def _staged_oracle(blk, n, hash_bits=8, pws=8, max_match=36):
    """The pre-fusion pipeline, stage by stage: the bit-identity reference
    for the fused kernel's (cand, lengths) outputs."""
    import jax.numpy as jnp

    words, hashes = ops.hash_positions(blk[: MAX_BLOCK + 3], hash_bits)
    cand = _candidates(hashes, n, hash_bits, pws)
    p = jnp.arange(MAX_BLOCK, dtype=jnp.int32)
    wc = jnp.take(words, jnp.clip(cand, 0, MAX_BLOCK - 1))
    valid4 = (cand >= 0) & (wc == words) & (p <= n - MF_LIMIT)
    lengths = ops.match_lengths(blk, cand, valid4, n, max_match=max_match)
    return lengths


@pytest.mark.parametrize("name", ["text", "all_zero_block", "structured",
                                  "tile_straddle", "incompressible_short",
                                  "rle_runs", "empty"])
def test_fused_pallas_equals_twin_elementwise(name):
    import jax.numpy as jnp

    data = _adversarial_corpus()[name]
    buf, n = pad_block(data)
    blk = jnp.where(jnp.arange(buf.shape[0]) < n,
                    jnp.asarray(buf, jnp.int32), 0)
    c_ref, l_ref = ops.fused_match_candidates(blk, jnp.int32(n),
                                              positions=MAX_BLOCK)
    c_pl, l_pl = ops.fused_match_candidates(blk, jnp.int32(n),
                                            positions=MAX_BLOCK,
                                            use_pallas=True)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pl), name)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pl), name)
    # Lengths must equal the staged sort-oracle chain exactly (0 where no
    # valid match, including every masked invalid-position corner).
    np.testing.assert_array_equal(
        np.asarray(l_ref), np.asarray(_staged_oracle(blk, jnp.int32(n))), name)
    lengths = np.asarray(l_ref)
    assert ((lengths == 0) | (lengths >= MIN_MATCH)).all()
    # Every reported candidate really is an earlier-window position.
    cand = np.asarray(c_ref)
    live = lengths > 0
    assert (cand[live] >= 0).all()
    assert (cand[live] // 8 < np.nonzero(live)[0] // 8).all()


# ---------------------------------------------------------------------------
# Bytes path + engine frames + the seed guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_bytes_path_roundtrip(use_pallas):
    import jax.numpy as jnp

    for name in ("text", "rle_runs", "all_zero_short"):
        data = _adversarial_corpus()[name]
        buf, n = pad_block(data)
        out_s, sz_s = compress_block_bytes(jnp.asarray(buf), jnp.int32(n),
                                           candidate_impl="sort")
        out_f, sz_f = compress_block_bytes(jnp.asarray(buf), jnp.int32(n),
                                           candidate_impl="fused",
                                           use_pallas=use_pallas)
        assert int(sz_f) == int(sz_s), name
        assert np.asarray(out_f).tobytes() == np.asarray(out_s).tobytes(), name


def _multiblock_corpus() -> bytes:
    rng = _rng()
    return (b"fused datapath corpus " * 9000
            + rng.integers(0, 256, MAX_BLOCK + 333, np.uint8).tobytes()
            + b"\x00" * (MAX_BLOCK + 17))


def test_engine_fused_frames_bit_identical():
    data = _multiblock_corpus()
    frames = {}
    for impl in ("sort", "scatter", "fused"):
        eng = LZ4Engine(micro_batch=2, candidate_impl=impl)
        frames[impl] = eng.compress(data)
        assert eng.stats.candidate_impl == impl
    assert frames["sort"] == frames["scatter"] == frames["fused"]
    assert decode_frame(frames["fused"]) == data
    # The Pallas kernel through the vmapped engine path, too.
    pl = LZ4Engine(micro_batch=2, candidate_impl="fused", use_pallas=True)
    assert pl.compress(data) == frames["sort"]
    # Composes with the records path and both device-emit drains.
    assert LZ4Engine(micro_batch=2, candidate_impl="fused",
                     device_emit=False).compress(data) == frames["sort"]
    assert LZ4Engine(micro_batch=2, candidate_impl="fused",
                     drain="full").compress(data) == frames["sort"]


def test_fused_guard_unchanged_from_seed():
    """Fused/auto engine frames must equal the seed-constructed frame.

    Reconstructs the frame exactly as the seed write path did — per-block
    `emit_block` of records fetched from the SORT path, raw passthrough
    when the in-graph size does not beat raw, checksums of the original
    chunk — so the new candidate impls can never silently drift the frame
    bytes while the datapath evolves.
    """
    import jax.numpy as jnp

    data = _multiblock_corpus()
    payloads, usizes, raws, crcs = [], [], [], []
    for i in range(0, len(data), MAX_BLOCK):
        chunk = data[i: i + MAX_BLOCK]
        buf, n = pad_block(chunk)
        rec = compress_block_records(jnp.asarray(buf), jnp.int32(n),
                                     candidate_impl="sort")
        if int(rec.size) >= n:
            payloads.append(chunk)
            raws.append(True)
        else:
            payloads.append(emit_block(chunk, np.asarray(rec.emit),
                                       np.asarray(rec.pos),
                                       np.asarray(rec.length),
                                       np.asarray(rec.offset), n))
            raws.append(False)
        usizes.append(n)
        crcs.append(block_crc(chunk))
    seed_frame = encode_frame(payloads, usizes, raws, checksums=crcs)
    assert LZ4Engine(candidate_impl="fused").compress(data) == seed_frame
    assert LZ4Engine(candidate_impl="auto").compress(data) == seed_frame
    assert LZ4Engine().compress(data) == seed_frame


# ---------------------------------------------------------------------------
# "auto" resolution
# ---------------------------------------------------------------------------

def test_resolve_candidate_impl():
    import jax

    assert resolve_candidate_impl("auto", backend="cpu") == "sortkey"
    assert resolve_candidate_impl("auto", backend="gpu") == "scatter"
    # "fused" is only auto-picked where the Pallas kernel actually runs:
    # TPU with use_pallas; without it the jnp twin would just be a slower
    # scatter, so auto falls back to scatter.
    assert resolve_candidate_impl("auto", backend="tpu",
                                  use_pallas=True) == "fused"
    assert resolve_candidate_impl("auto", backend="tpu") == "scatter"
    for impl in CANDIDATE_IMPLS:
        assert resolve_candidate_impl(impl, backend="cpu") == impl
        assert resolve_candidate_impl(impl, backend="tpu",
                                      use_pallas=True) == impl
    with pytest.raises(ValueError):
        resolve_candidate_impl("bogus")
    with pytest.raises(ValueError):
        LZ4Engine(candidate_impl="bogus")
    # The engine resolves ONCE at construction and records what ran.
    eng = LZ4Engine(micro_batch=1)
    assert eng.candidate_impl == resolve_candidate_impl(
        "auto", backend=jax.default_backend())
    eng.compress(b"auto resolution " * 500)
    assert eng.stats.candidate_impl == eng.candidate_impl
    assert eng.stats.candidate_impl != "auto"
    # Default records ("auto") match the explicit resolved impl's records.
    data = _adversarial_corpus()["text"]
    _assert_records_equal(_records(data, "auto"),
                          _records(data, eng.candidate_impl))


# ---------------------------------------------------------------------------
# In-graph CRC-32 (the device-verify satellite)
# ---------------------------------------------------------------------------

def test_crc32_bytes_matches_binascii():
    import jax.numpy as jnp

    rng = _rng()
    cap = 4096
    buf = rng.integers(0, 256, cap, np.uint8)
    for n in (0, 1, 3, 7, 8, 9, 15, 16, 255, 256, 257, 1000, cap - 1, cap):
        got = int(ops.crc32_bytes(jnp.asarray(buf), jnp.int32(n)))
        want = binascii.crc32(buf[:n].tobytes()) & 0xFFFFFFFF
        assert got == want, n
    # Full 64 KB block (the decode row shape) and an all-zero run.
    big = rng.integers(0, 256, MAX_BLOCK, np.uint8)
    assert int(ops.crc32_bytes(jnp.asarray(big), jnp.int32(MAX_BLOCK))) == \
        binascii.crc32(big.tobytes()) & 0xFFFFFFFF
    zeros = np.zeros(MAX_BLOCK, np.uint8)
    assert int(ops.crc32_bytes(jnp.asarray(zeros), jnp.int32(70))) == \
        binascii.crc32(bytes(70)) & 0xFFFFFFFF
    # Content past n must not leak into the checksum.
    buf2 = buf.copy()
    buf2[100:] ^= 0xFF
    assert int(ops.crc32_bytes(jnp.asarray(buf2), jnp.int32(100))) == \
        int(ops.crc32_bytes(jnp.asarray(buf), jnp.int32(100)))
