"""Device-side emission: bit-identity against the host emitter oracle.

Covers the PR-3 acceptance surface:
  * `compress_block_bytes` (records -> bytes entirely in-graph) is
    byte-identical to `emit_block` (the host oracle) on random and
    adversarial corpora — incompressible, all-zero, RLE runs that end at
    token-nibble and extension-byte boundaries;
  * the Pallas scatter-emit kernel equals the jnp gather fallback;
  * the in-graph size equals `BlockRecords.size` and never exceeds OUT_CAP;
  * `LZ4Engine(device_emit=True)` frames are bit-identical to
    `device_emit=False` frames, which in turn are guarded against drift
    from the seed construction (emit_block + encode_frame by hand);
  * the device-emit path transfers fewer device->host bytes than the
    records path (EngineStats.host_bytes).
"""
import numpy as np
import pytest

from repro.core import LZ4Engine, decode_block, decode_frame, encode_frame
from repro.core.emitter import emit_block, emit_block_from_records
from repro.core.frame import block_crc
from repro.core.jax_compressor import (
    OUT_CAP,
    compress_block_bytes,
    compress_block_records,
    pad_block,
)
from repro.core.lz4_types import MAX_BLOCK


def _rng():
    return np.random.default_rng(20260730)


def _adversarial_corpus() -> dict[str, bytes]:
    """Random + adversarial blocks aimed at emit-layout edge cases."""
    rng = _rng()
    return {
        "empty": b"",
        "one_byte": b"\x07",
        "all_zero_block": b"\x00" * MAX_BLOCK,
        "all_zero_short": b"\x00" * 1000,
        "incompressible": rng.integers(0, 256, MAX_BLOCK, np.uint8).tobytes(),
        "incompressible_short": rng.integers(0, 256, 4096, np.uint8).tobytes(),
        # Literal counts straddling the token-nibble (15) and first
        # extension-byte (270) boundaries, then a match so the literals are
        # mid-block rather than the final sequence.
        "lit_nibble_edge": bytes(rng.integers(0, 256, 14, np.uint8)) + b"Z" * 64,
        "lit_nibble_edge2": bytes(rng.integers(0, 256, 15, np.uint8)) + b"Z" * 64,
        "lit_ext_edge": bytes(rng.integers(0, 256, 269, np.uint8)) + b"Z" * 64,
        "lit_ext_edge2": bytes(rng.integers(0, 256, 270, np.uint8)) + b"Z" * 64,
        # RLE run ending exactly at the block boundary (final-literals rule
        # interacts with the run) and just short of it.
        "rle_to_boundary": b"\xaa" * MAX_BLOCK,
        "rle_near_boundary": bytes(rng.integers(0, 256, 100, np.uint8)) + b"\xbb" * (MAX_BLOCK - 100),
        "rle_then_tail": b"\xcc" * (MAX_BLOCK - 7) + b"tail567"[:7],
        "text": b"the quick brown fox jumps over the lazy dog. " * 1000,
        "low_entropy": rng.integers(0, 4, MAX_BLOCK, np.uint8).tobytes(),
        "structured": bytes(rng.integers(0, 16, 64, np.uint8)) * 1024,
    }


def _oracle_and_device(data: bytes, use_pallas: bool = False):
    import jax.numpy as jnp

    buf, n = pad_block(data)
    rec = compress_block_records(jnp.asarray(buf), jnp.int32(n),
                                 use_pallas=use_pallas)
    oracle = emit_block_from_records(data, rec, n)
    out, size = compress_block_bytes(jnp.asarray(buf), jnp.int32(n),
                                     use_pallas=use_pallas)
    return rec, oracle, np.asarray(out), int(size)


# ---------------------------------------------------------------------------
# Bit-identity vs the host oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(_adversarial_corpus().keys()))
def test_device_emit_bit_identical_to_oracle(name):
    data = _adversarial_corpus()[name]
    rec, oracle, out, size = _oracle_and_device(data)
    assert size == int(rec.size)          # layout total == in-graph plan size
    assert size <= OUT_CAP
    assert out[:size].tobytes() == oracle
    assert np.all(out[size:] == 0)        # padding region is zeroed
    if data:
        assert decode_block(out[:size].tobytes(), max_out=len(data)) == data


def test_device_emit_random_lengths():
    rng = _rng()
    for size in (1, 14, 15, 16, 255, 270, 271, 4096, MAX_BLOCK - 1):
        data = bytes(rng.integers(0, 8, size, np.uint8))
        _, oracle, out, s = _oracle_and_device(data)
        assert out[:s].tobytes() == oracle, size


@pytest.mark.parametrize("name", ["text", "rle_to_boundary", "lit_ext_edge",
                                  "incompressible_short", "all_zero_short"])
def test_pallas_emit_equals_fallback(name):
    data = _adversarial_corpus()[name]
    _, oracle, out_ref, s_ref = _oracle_and_device(data, use_pallas=False)
    _, _, out_pl, s_pl = _oracle_and_device(data, use_pallas=True)
    assert s_pl == s_ref
    assert out_pl.tobytes() == out_ref.tobytes()
    assert out_pl[:s_pl].tobytes() == oracle


# ---------------------------------------------------------------------------
# Engine-level equality and the seed guard
# ---------------------------------------------------------------------------

def _multiblock_corpus() -> bytes:
    rng = _rng()
    return (b"engine level corpus " * 9000                      # compressible
            + rng.integers(0, 256, MAX_BLOCK + 333, np.uint8).tobytes()  # raw
            + b"\x00" * (MAX_BLOCK + 17))                       # RLE


def test_engine_device_emit_frames_bit_identical():
    data = _multiblock_corpus()
    dev = LZ4Engine(micro_batch=2, device_emit=True)
    host = LZ4Engine(micro_batch=2, device_emit=False)
    f_dev, f_host = dev.compress(data), host.compress(data)
    assert f_dev == f_host
    assert decode_frame(f_dev) == data
    # Device emission must fetch fewer bytes per block than the records path.
    assert dev.stats.host_bytes < host.stats.host_bytes
    assert dev.stats.host_bytes > 0


def test_engine_device_emit_blocks_bit_identical():
    data = _multiblock_corpus()
    assert (LZ4Engine(device_emit=True).compress_to_blocks(data)
            == LZ4Engine(device_emit=False).compress_to_blocks(data))


def test_host_path_guard_unchanged_from_seed():
    """device_emit=False must still produce the seed's frame bytes.

    Reconstructs the frame exactly as the seed write path did — per-block
    `emit_block` of the fetched records, raw passthrough when the in-graph
    size does not beat raw, v2 checksums of the uncompressed chunk — and
    asserts byte equality, so the host path can never silently drift while
    the device path evolves.
    """
    import jax.numpy as jnp

    data = _multiblock_corpus()
    payloads, usizes, raws, crcs = [], [], [], []
    for i in range(0, len(data), MAX_BLOCK):
        chunk = data[i: i + MAX_BLOCK]
        buf, n = pad_block(chunk)
        rec = compress_block_records(jnp.asarray(buf), jnp.int32(n))
        if int(rec.size) >= n:
            payloads.append(chunk)
            raws.append(True)
        else:
            payloads.append(emit_block(chunk, np.asarray(rec.emit),
                                       np.asarray(rec.pos), np.asarray(rec.length),
                                       np.asarray(rec.offset), n))
            raws.append(False)
        usizes.append(n)
        crcs.append(block_crc(chunk))
    seed_frame = encode_frame(payloads, usizes, raws, checksums=crcs)
    assert LZ4Engine(device_emit=False).compress(data) == seed_frame
    assert LZ4Engine(device_emit=True).compress(data) == seed_frame


def test_host_path_uses_emit_block(monkeypatch):
    """The switch is real: emit_block runs on host iff device_emit=False."""
    import repro.core.engine as engine_mod

    calls = []
    orig = engine_mod.emit_block
    monkeypatch.setattr(engine_mod, "emit_block",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    data = b"switchable emission " * 2000
    LZ4Engine(device_emit=True).compress(data)
    assert calls == []
    LZ4Engine(device_emit=False).compress(data)
    assert len(calls) == 1


def test_engine_raw_passthrough_identical_across_paths():
    # Incompressible input: size >= n, both paths must store raw payloads.
    data = _rng().integers(0, 256, 2 * MAX_BLOCK, np.uint8).tobytes()
    dev, host = LZ4Engine(device_emit=True), LZ4Engine(device_emit=False)
    assert dev.compress(data) == host.compress(data)
    assert dev.stats.raw_blocks == host.stats.raw_blocks == 2
