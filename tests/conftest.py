"""Shared fixtures — the seeded fault-injection (`chaos`) harness.

`chaos` arms a process-global `repro.resilience.inject.FaultInjector` for
one test and guarantees disarm on teardown, so crash points and transient
I/O faults fire inside the code under test without monkeypatching:

    def test_torn_save(chaos, tmp_path):
        inj = chaos(seed=3, crash_at="checkpoint.rename")
        with pytest.raises(InjectedCrash):
            checkpoint.save(tmp_path, 2, tree)
        assert checkpoint.latest_step(tmp_path) == 1

The same injector drives the benchmark ``--chaos`` flags and the CI chaos
matrix, so every layer reproduces failures from one seeded source.
"""
import pytest

from repro.resilience.inject import FaultInjector, install


@pytest.fixture
def chaos():
    """Factory: ``chaos(seed=..., crash_at=..., fail={...}, slow={...})``
    arms a `FaultInjector` (disarmed automatically at teardown)."""
    active = []

    def arm(seed: int = 0, **kw) -> FaultInjector:
        inj = FaultInjector(seed=seed, **kw)
        cm = install(inj)
        cm.__enter__()
        active.append(cm)
        return inj

    yield arm
    while active:
        active.pop().__exit__(None, None, None)
